//! **Test-only reference engine** — the pre-refactor (PR 3) event-driven
//! simulator, kept verbatim so the optimized [`super::engine`] can be
//! proven byte-identical against it on seeded serve streams (the
//! `integration_sim_equiv` suite). Per-event costs here are deliberately
//! the *old* linear scans (`issue_phase` over every dispatch ever created,
//! `retain`/`contains` membership walks, `device_load` recomputed per
//! policy call); do **not** use it outside equivalence tests or the
//! before/after rows of `benches/serve_scale.rs` /
//! `benches/serve_overload.rs`. It schedules through the **view-based
//! reference policies** ([`crate::sched::reference`]) — the pre-PR-5
//! `Policy` trait whose `select` scans a per-call [`SchedView`].

use super::engine::{CompMeta, SimConfig, SimResult};
use crate::cost::{contention, CostModel};
use crate::error::{Error, Result};
use crate::graph::{Dag, KernelId, Partition};
use crate::platform::{DeviceId, Platform};
use crate::queue::{setup_cq, CmdId, CommandKind, CommandQueues};
use crate::sched::reference::{Policy, SchedView};
use crate::sched::{component_ranks, ResidentTenant};
use crate::trace::{Lane, Span, Trace};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

#[derive(Debug, Clone, Copy, PartialEq)]
enum CmdState {
    Pending,
    Issued,
    Done,
}

struct Dispatch {
    cq: CommandQueues,
    device: DeviceId,
    /// Commands become issuable after this instant (select + setup_cq).
    ready_at: f64,
    /// Set when the component was preempted: the dispatch is dead — no
    /// further commands issue, in-flight completions are dropped, and a
    /// fresh dispatch is created when the component is re-selected.
    cancelled: bool,
    /// EFT booking added to `est_free[device]` at dispatch — rolled back
    /// on displacement so repeated preemptions don't inflate the device's
    /// estimated backlog.
    est_committed: f64,
    state: Vec<CmdState>,
    /// Next unissued index per queue (in-order execution).
    queue_next: Vec<usize>,
    cmds_remaining: usize,
    /// Remaining commands per kernel (callback firing condition).
    kernel_cmds_left: Vec<(KernelId, usize)>,
    /// Kernels with registered callbacks not yet fired.
    callbacks_left: usize,
    /// Precomputed callback classification (§Perf: recomputing FRONT/END
    /// per command completion dominated the simulator profile).
    cb_kernels: Vec<KernelId>,
    async_kernels: Vec<KernelId>,
}

struct Run {
    disp: usize,
    cmd: CmdId,
    kernel: KernelId,
    device: DeviceId,
    queue: usize,
    /// Remaining work in solo-seconds.
    remaining: f64,
    occupancy: f64,
    started: f64,
}

#[derive(Debug, Clone, Copy)]
enum EvKind {
    /// setup_cq finished; dispatch commands may issue (the id is carried
    /// for trace/debug symmetry; issue_phase scans ready dispatches).
    #[allow(dead_code)]
    DispatchReady(usize),
    /// A host-side (CPU shared-memory) transfer completed.
    TransferDone { disp: usize, cmd: CmdId },
    /// The DMA copy engine finished its current transfer.
    CopyDone { engine: usize },
    /// A kernel's completion callback ran on the host.
    Callback { disp: usize, kernel: KernelId },
    /// A served DAG request arrived: its component may now join the frontier
    /// (multi-DAG serving; never emitted when all release times are zero).
    Release { comp: usize },
}

struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Ev {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&o.t)
            .then_with(|| self.seq.cmp(&o.seq))
    }
}

struct CopyEngine {
    /// FIFO of queued transfers.
    queue: VecDeque<(usize, CmdId)>,
    /// Currently transferring, if any.
    current: Option<(usize, CmdId)>,
}

/// Pre-refactor [`super::engine::simulate`], verbatim — equivalence-test
/// reference only.
pub fn simulate_ref(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &SimConfig,
) -> Result<SimResult> {
    Engine::new(dag, partition, platform, cost, policy, cfg, None)?.run()
}

/// Pre-refactor [`super::engine::simulate_served`], verbatim —
/// equivalence-test reference only.
#[allow(clippy::too_many_arguments)]
pub fn simulate_served_ref(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &SimConfig,
    meta: &[CompMeta],
) -> Result<SimResult> {
    if meta.len() != partition.components.len() {
        return Err(Error::Sched(format!(
            "serving metadata for {} components, partition has {}",
            meta.len(),
            partition.components.len()
        )));
    }
    for m in meta {
        if !m.release.is_finite() || m.release < 0.0 {
            return Err(Error::Sched(format!("invalid release time {}", m.release)));
        }
        // Deadlines are absolute instants: zero or even negative just means
        // "already due" (an ordinary miss), so only NaN is malformed.
        // Relative-budget validation (> 0) belongs to admission.
        if m.deadline.is_nan() {
            return Err(Error::Sched("invalid deadline NaN".into()));
        }
    }
    Engine::new(dag, partition, platform, cost, policy, cfg, Some(meta))?.run()
}

struct Engine<'a> {
    dag: &'a Dag,
    partition: &'a Partition,
    platform: &'a Platform,
    cost: &'a dyn CostModel,
    policy: &'a mut dyn Policy,
    cfg: &'a SimConfig,

    now: f64,
    seq: u64,
    heap: BinaryHeap<Reverse<Ev>>,
    trace: Trace,

    // Scheduler state (Algorithm 1).
    frontier: Vec<usize>,
    comp_rank: Vec<f64>,
    available: Vec<DeviceId>,
    est_free: Vec<f64>,
    /// Earliest instant each component may join the frontier (serving).
    release: Vec<f64>,
    /// Absolute deadline per component (∞ when the request has none).
    deadline: Vec<f64>,
    /// Request priority per component (0 default).
    priority: Vec<u32>,
    /// Components currently resident per device (multi-tenant serving).
    tenants: Vec<usize>,
    /// Outstanding external predecessor kernels per component.
    ext_preds_left: Vec<usize>,
    /// comp list each kernel unblocks when globally finished.
    unblocks: Vec<Vec<usize>>,
    kernel_finished: Vec<bool>,
    comp_dispatched: Vec<bool>,
    comp_finish: Vec<f64>,
    comp_device: Vec<DeviceId>,
    comps_done: usize,
    /// Fraction of each kernel's solo execution already performed —
    /// preserved across preemption so displaced work re-runs only its
    /// remaining solo-seconds (transfers are re-staged in full).
    kernel_frac: Vec<f64>,
    /// Live dispatch index per component (None once finished/displaced).
    comp_active_disp: Vec<Option<usize>>,
    preemptions: usize,

    // Execution state.
    dispatches: Vec<Dispatch>,
    runs: Vec<Run>,
    copy_engines: Vec<CopyEngine>,
    last_cmd_done: f64,
}

const EPS: f64 = 1e-12;

impl<'a> Engine<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        dag: &'a Dag,
        partition: &'a Partition,
        platform: &'a Platform,
        cost: &'a dyn CostModel,
        policy: &'a mut dyn Policy,
        cfg: &'a SimConfig,
        meta: Option<&[CompMeta]>,
    ) -> Result<Self> {
        let ncomp = partition.components.len();
        // Kernel-level unblock lists: producer kernel -> consumer components.
        let mut unblocks: Vec<Vec<usize>> = vec![Vec::new(); dag.num_kernels()];
        let mut ext_pred_sets: Vec<Vec<KernelId>> = vec![Vec::new(); ncomp];
        for &(src, dst) in &dag.buffer_edges {
            let pk = dag.buffers[src].kernel;
            let ck = dag.buffers[dst].kernel;
            let pc = partition.assignment[pk];
            let cc = partition.assignment[ck];
            if pc != cc {
                if !unblocks[pk].contains(&cc) {
                    unblocks[pk].push(cc);
                }
                if !ext_pred_sets[cc].contains(&pk) {
                    ext_pred_sets[cc].push(pk);
                }
            }
        }
        let ext_preds_left: Vec<usize> = ext_pred_sets.iter().map(|s| s.len()).collect();
        let comp_rank = component_ranks(dag, partition, platform, cost);
        let release: Vec<f64> = meta
            .map(|m| m.iter().map(|c| c.release).collect())
            .unwrap_or_else(|| vec![0.0; ncomp]);
        let deadline: Vec<f64> = meta
            .map(|m| m.iter().map(|c| c.deadline).collect())
            .unwrap_or_else(|| vec![f64::INFINITY; ncomp]);
        let priority: Vec<u32> = meta
            .map(|m| m.iter().map(|c| c.priority).collect())
            .unwrap_or_else(|| vec![0; ncomp]);
        let mut frontier: Vec<usize> = (0..ncomp)
            .filter(|&c| ext_preds_left[c] == 0 && release[c] <= 0.0)
            .collect();
        frontier.sort_by(|&a, &b| comp_rank[b].total_cmp(&comp_rank[a]));
        let available: Vec<DeviceId> = platform
            .devices
            .iter()
            .filter(|d| d.num_queues > 0)
            .map(|d| d.id)
            .collect();
        if available.is_empty() {
            return Err(Error::Sched("no device has command queues".into()));
        }
        Ok(Engine {
            dag,
            partition,
            platform,
            cost,
            policy,
            cfg,
            now: 0.0,
            seq: 0,
            heap: BinaryHeap::new(),
            trace: Trace::default(),
            frontier,
            comp_rank,
            available,
            est_free: vec![0.0; platform.devices.len()],
            release,
            deadline,
            priority,
            tenants: vec![0; platform.devices.len()],
            ext_preds_left,
            unblocks,
            kernel_finished: vec![false; dag.num_kernels()],
            comp_dispatched: vec![false; ncomp],
            comp_finish: vec![f64::NAN; ncomp],
            comp_device: vec![usize::MAX; ncomp],
            comps_done: 0,
            kernel_frac: vec![0.0; dag.num_kernels()],
            comp_active_disp: vec![None; ncomp],
            preemptions: 0,
            dispatches: Vec::new(),
            runs: Vec::new(),
            copy_engines: (0..platform.copy_engines.max(1))
                .map(|_| CopyEngine {
                    queue: VecDeque::new(),
                    current: None,
                })
                .collect(),
            last_cmd_done: 0.0,
        })
    }

    fn push_ev(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Reverse(Ev {
            t,
            seq: self.seq,
            kind,
        }));
    }

    // ---------------------------------------------------------- scheduling

    /// Current occupancy committed per device (Σ occupancy of running
    /// kernels) — the cross-DAG load signal exposed to policies.
    fn device_load(&self) -> Vec<f64> {
        let mut load = vec![0.0; self.platform.devices.len()];
        for r in &self.runs {
            load[r.device] += r.occupancy;
        }
        load
    }

    fn scheduler_phase(&mut self) {
        // One preemption is allowed per blocked `select`; if the policy
        // displaces a tenant but *still* cannot place anything, stop —
        // otherwise a misbehaving policy could spin displacing tenants.
        // The budget additionally bounds displace→select→displace churn
        // within one phase: a Policy violating the strict-dominance
        // contract (preempting a victim it immediately re-selects) would
        // otherwise livelock here at a fixed timestamp, out of reach of
        // run()'s max_events backstop. Legitimate chains are bounded by
        // the component count.
        let mut preempt_budget = self.partition.components.len().max(8);
        let mut retry_after_preempt = false;
        loop {
            let load = self.device_load();
            let view = SchedView {
                now: self.now,
                frontier: &self.frontier,
                available: &self.available,
                platform: self.platform,
                partition: self.partition,
                dag: self.dag,
                est_free: &self.est_free,
                device_load: &load,
                deadline: &self.deadline,
                priority: &self.priority,
                cost: self.cost,
            };
            if let Some((comp, dev)) = self.policy.select(&view) {
                retry_after_preempt = false;
                self.dispatch(comp, dev);
                continue;
            }
            if retry_after_preempt
                || preempt_budget == 0
                || self.frontier.is_empty()
                || !self.policy.can_preempt()
            {
                break;
            }
            // Candidate victims: resident components with commands still
            // outstanding. A component that only awaits its completion
            // callbacks frees no compute when displaced — its tenant slot
            // returns within ~callback_latency anyway, while a displacement
            // would force a full transfer re-stage.
            let resident: Vec<ResidentTenant> = self
                .comp_active_disp
                .iter()
                .enumerate()
                .filter_map(|(c, di)| {
                    di.filter(|&d| self.dispatches[d].cmds_remaining > 0)
                        .map(|d| ResidentTenant {
                            comp: c,
                            device: self.dispatches[d].device,
                        })
                })
                .collect();
            if resident.is_empty() {
                break;
            }
            match self.policy.preempt(&view, &resident) {
                Some(victim) if self.displace(victim) => {
                    preempt_budget -= 1;
                    retry_after_preempt = true;
                }
                _ => break,
            }
        }
    }

    fn dispatch(&mut self, comp: usize, dev: DeviceId) {
        assert!(!self.comp_dispatched[comp], "component {comp} re-dispatched");
        self.comp_dispatched[comp] = true;
        self.frontier.retain(|&c| c != comp);
        self.tenants[dev] += 1;
        if self.tenants[dev] >= self.cfg.max_tenants.max(1) {
            self.available.retain(|&d| d != dev);
        }
        self.comp_device[comp] = dev;

        // setup_cq runs on a child thread: commands are issuable after the
        // per-command enqueue overhead has elapsed.
        let mut device = self.platform.device(dev).clone();
        device.num_queues = self.policy.queues_for(&device);
        let cq = setup_cq(self.dag, self.partition, comp, &device);
        let setup = cq.num_commands() as f64 * self.platform.enqueue_overhead;
        let ready_at = self.now + setup;
        self.trace.push(Span {
            label: format!("setup c{comp}"),
            lane: Lane::Host,
            start: self.now,
            end: ready_at,
            cmd: None,
            kernel: None,
        });

        // Commit an EFT estimate for HEFT's est_free bookkeeping. Under
        // multi-tenancy the device backlog accumulates across residents.
        let solo: f64 = self.partition.components[comp]
            .kernels
            .iter()
            .map(|&k| self.cost.exec_time(&self.dag.kernels[k], &device))
            .sum();
        let transfers: f64 = cq
            .commands
            .iter()
            .filter_map(|c| c.transfer_buffer())
            .map(|b| self.platform.transfer_time(dev, self.dag.buffers[b].size_bytes))
            .sum();
        let est_committed = solo + transfers + self.platform.callback_latency;
        self.est_free[dev] = self.est_free[dev].max(ready_at) + est_committed;

        let mut kernel_cmds_left: Vec<(KernelId, usize)> = Vec::new();
        for c in &cq.commands {
            match kernel_cmds_left.iter_mut().find(|(k, _)| *k == c.kernel) {
                Some((_, n)) => *n += 1,
                None => kernel_cmds_left.push((c.kernel, 1)),
            }
        }
        let cb_kernels = self.partition.callback_kernels(self.dag, comp);
        let async_kernels = self.partition.async_callback_kernels(self.dag, comp);
        let d = Dispatch {
            state: vec![CmdState::Pending; cq.num_commands()],
            queue_next: vec![0; cq.queues.len()],
            cmds_remaining: cq.num_commands(),
            kernel_cmds_left,
            callbacks_left: cb_kernels.len(),
            cb_kernels,
            async_kernels,
            cq,
            device: dev,
            ready_at,
            cancelled: false,
            est_committed,
        };
        let idx = self.dispatches.len();
        self.dispatches.push(d);
        self.comp_active_disp[comp] = Some(idx);
        self.push_ev(ready_at, EvKind::DispatchReady(idx));
    }

    /// Preempt `victim` at command-queue granularity: kernels that already
    /// completed stay completed (their callbacks still unblock successors),
    /// running kernels are stopped with their progress credited to
    /// [`Engine::kernel_frac`] (remaining solo-seconds preserved), queued
    /// commands are cancelled, the tenant slot is returned, and the
    /// component re-enters the frontier for a later re-dispatch (which
    /// re-stages its transfers — the preemption penalty). Returns false if
    /// `victim` is not currently resident.
    fn displace(&mut self, victim: usize) -> bool {
        let Some(di) = self.comp_active_disp.get(victim).copied().flatten() else {
            return false;
        };
        // Stop running kernels of this dispatch, crediting partial work.
        let mut i = 0;
        while i < self.runs.len() {
            if self.runs[i].disp != di {
                i += 1;
                continue;
            }
            let r = self.runs.swap_remove(i);
            let device = self.platform.device(r.device);
            let full = self.cost.exec_time(&self.dag.kernels[r.kernel], device);
            let done = if full > 0.0 {
                (1.0 - r.remaining / full).clamp(0.0, 1.0)
            } else {
                1.0
            };
            self.kernel_frac[r.kernel] = self.kernel_frac[r.kernel].max(done);
            if self.now > r.started {
                let name = &self.dag.kernels[r.kernel].name;
                self.trace.push(Span {
                    label: format!("{name}{}!", r.kernel),
                    lane: Lane::Device {
                        dev: r.device,
                        slot: r.queue,
                    },
                    start: r.started,
                    end: self.now,
                    cmd: Some(r.cmd),
                    kernel: Some(r.kernel),
                });
            }
        }
        // Drop queued (not yet started) DMA transfers; an in-flight one
        // finishes physically but its completion is ignored (`cancelled`).
        for e in &mut self.copy_engines {
            e.queue.retain(|&(d, _)| d != di);
        }
        let dev = self.dispatches[di].device;
        self.dispatches[di].cancelled = true;
        self.comp_active_disp[victim] = None;
        self.comp_dispatched[victim] = false;
        self.tenants[dev] -= 1;
        if !self.available.contains(&dev) {
            self.available.push(dev);
        }
        // Roll back the EFT booking made at dispatch (the re-dispatch will
        // book afresh); partial progress is forfeited with it.
        self.est_free[dev] = (self.est_free[dev] - self.dispatches[di].est_committed).max(self.now);
        if self.tenants[dev] == 0 {
            self.est_free[dev] = self.now;
        }
        self.preemptions += 1;
        self.trace.push(Span {
            label: format!("preempt c{victim}"),
            lane: Lane::Host,
            start: self.now,
            end: self.now,
            cmd: None,
            kernel: None,
        });
        self.enter_frontier(victim);
        true
    }

    // ------------------------------------------------------------- issuing

    /// Issue every currently eligible command. In-order queues: only each
    /// queue's head candidate is considered; cross-queue deps must be Done.
    fn issue_phase(&mut self) {
        let mut progressed = true;
        while progressed {
            progressed = false;
            for di in 0..self.dispatches.len() {
                // §Perf: skip drained, cancelled, or not-yet-ready
                // dispatches — dynamic policies accumulate one dispatch per
                // kernel, and scanning finished ones made issue_phase
                // O(kernels) per event.
                if self.dispatches[di].cmds_remaining == 0
                    || self.dispatches[di].cancelled
                    || self.dispatches[di].ready_at > self.now + EPS
                {
                    continue;
                }
                for q in 0..self.dispatches[di].cq.queues.len() {
                    // In-order queue: a command may issue only once every
                    // earlier command in the same queue has *completed*.
                    loop {
                        let d = &self.dispatches[di];
                        let Some(&cmd) = d.cq.queues[q].get(d.queue_next[q]) else {
                            break;
                        };
                        match d.state[cmd] {
                            CmdState::Done => {
                                self.dispatches[di].queue_next[q] += 1;
                                continue;
                            }
                            CmdState::Issued => break, // head still running
                            CmdState::Pending => {}
                        }
                        let deps_ok = d
                            .cq
                            .deps_of(cmd)
                            .iter()
                            .all(|&dep| d.state[dep] == CmdState::Done);
                        if !deps_ok || !self.try_issue(di, cmd) {
                            break;
                        }
                        progressed = true;
                        break; // issued: wait for completion before the next
                    }
                }
            }
        }
    }

    /// Attempt to issue one command; false if a resource gate blocks it.
    fn try_issue(&mut self, di: usize, cmd: CmdId) -> bool {
        let d = &self.dispatches[di];
        let dev_id = d.device;
        let kind = d.cq.commands[cmd].kind;
        let kernel = d.cq.commands[cmd].kernel;
        let queue = d.cq.commands[cmd].queue;
        match kind {
            CommandKind::NdRange => {
                // Hardware concurrency cap (Hyper-Q / CPU fission width).
                let running = self
                    .runs
                    .iter()
                    .filter(|r| r.device == dev_id)
                    .count();
                if running >= self.platform.device(dev_id).hw_queues {
                    return false;
                }
                let device = self.platform.device(dev_id);
                let node = &self.dag.kernels[kernel];
                // Preempted-and-re-dispatched kernels only owe their
                // remaining solo-seconds (kernel_frac credits prior runs;
                // fully finished kernels replay instantly).
                let full = self.cost.exec_time(node, device);
                let remaining = full * (1.0 - self.kernel_frac[kernel]).max(0.0);
                self.runs.push(Run {
                    disp: di,
                    cmd,
                    kernel,
                    device: dev_id,
                    queue,
                    remaining,
                    occupancy: contention::occupancy(node, device),
                    started: self.now,
                });
                self.dispatches[di].state[cmd] = CmdState::Issued;
                true
            }
            CommandKind::Write { buffer } | CommandKind::Read { buffer } => {
                self.dispatches[di].state[cmd] = CmdState::Issued;
                if self.platform.device(dev_id).shares_host_memory {
                    // Zero-copy map: completes after a token latency, no DMA.
                    let t = self.now + self.platform.transfer_time(dev_id, 0);
                    self.push_ev(t, EvKind::TransferDone { disp: di, cmd });
                } else {
                    let _ = buffer;
                    // Route to a DMA engine (one per GPU on scaled platforms).
                    let e = dev_id % self.copy_engines.len();
                    self.copy_engines[e].queue.push_back((di, cmd));
                    self.pump_copy_engine(e);
                }
                true
            }
        }
    }

    fn pump_copy_engine(&mut self, e: usize) {
        if self.copy_engines[e].current.is_some() {
            return;
        }
        let Some((di, cmd)) = self.copy_engines[e].queue.pop_front() else {
            return;
        };
        let d = &self.dispatches[di];
        let buffer = d.cq.commands[cmd].transfer_buffer().expect("transfer cmd");
        let bytes = self.dag.buffers[buffer].size_bytes;
        let dt = self.platform.transfer_time(d.device, bytes);
        let dir = match d.cq.commands[cmd].kind {
            CommandKind::Write { .. } => "w",
            _ => "r",
        };
        self.trace.push(Span {
            label: format!("{dir}{buffer}"),
            lane: Lane::CopyEngine { idx: e },
            start: self.now,
            end: self.now + dt,
            cmd: Some(cmd),
            kernel: Some(d.cq.commands[cmd].kernel),
        });
        self.copy_engines[e].current = Some((di, cmd));
        self.push_ev(self.now + dt, EvKind::CopyDone { engine: e });
    }

    // ---------------------------------------------------------- completion

    fn command_done(&mut self, di: usize, cmd: CmdId) {
        if self.dispatches[di].cancelled {
            // Completion belonging to a preempted dispatch (e.g. an
            // in-flight DMA or a zero-copy map that outlived displacement):
            // the work is void, the re-dispatch replays it.
            return;
        }
        let d = &mut self.dispatches[di];
        debug_assert_eq!(d.state[cmd], CmdState::Issued);
        d.state[cmd] = CmdState::Done;
        d.cmds_remaining -= 1;
        self.last_cmd_done = self.last_cmd_done.max(self.now);
        let kernel = d.cq.commands[cmd].kernel;
        let entry = d
            .kernel_cmds_left
            .iter_mut()
            .find(|(k, _)| *k == kernel)
            .expect("kernel tracked");
        entry.1 -= 1;
        let kernel_complete = entry.1 == 0;
        if kernel_complete {
            let tracked = d.cb_kernels.contains(&kernel);
            if tracked {
                let needs_async = d.async_kernels.contains(&kernel);
                let delay = if needs_async {
                    // clSetEventCallback path: base thread latency plus host
                    // starvation while the CPU device crunches kernels
                    // (Fig. 13(a)): the callback thread waits for a share of
                    // the largest remaining CPU kernel.
                    let cpu_remaining = self
                        .runs
                        .iter()
                        .filter(|r| {
                            self.platform.device(r.device).dtype
                                == crate::platform::DeviceType::Cpu
                        })
                        .map(|r| r.remaining)
                        .fold(0.0, f64::max);
                    self.platform.callback_latency
                        + self.cfg.host_starvation_fraction * cpu_remaining
                } else {
                    // Blocking-wait path (no inter-edge reads): the dispatch
                    // child thread wakes straight out of clFinish — the
                    // clustering advantage (§5 comparative evaluation).
                    self.platform.wait_latency
                };
                self.push_ev(self.now + delay, EvKind::Callback { disp: di, kernel });
            } else {
                // IN(T) kernels finish silently (intra deps only).
                self.kernel_finished[kernel] = true;
            }
        }
    }

    fn handle_callback(&mut self, di: usize, kernel: KernelId) {
        // A preempted-and-re-run kernel fires its callback again; only the
        // first firing may decrement successor dependency counts.
        let first_completion = !self.kernel_finished[kernel];
        self.kernel_finished[kernel] = true;
        let comp = self.dispatches[di].cq.component;
        if first_completion {
            // update_task_queue: successors that became ready join F —
            // unless their request has not arrived yet (serving), in which
            // case the release event re-examines them.
            let unblocked = self.unblocks[kernel].clone();
            for uc in unblocked {
                // A component is ready when all external producers are done.
                self.ext_preds_left[uc] -= 1;
                if self.ext_preds_left[uc] == 0 && !self.comp_dispatched[uc] {
                    if self.release[uc] > self.now + EPS {
                        self.push_ev(self.release[uc], EvKind::Release { comp: uc });
                    } else {
                        self.enter_frontier(uc);
                    }
                }
            }
        }
        if self.dispatches[di].cancelled {
            // Callback of a displaced dispatch: the tenant slot was already
            // returned at displacement; completed-kernel bookkeeping above
            // still counts (command-queue-granularity preemption).
            return;
        }
        // return_device (one tenant slot) once the component has finished.
        let d = &mut self.dispatches[di];
        d.callbacks_left -= 1;
        if d.callbacks_left == 0 {
            debug_assert_eq!(d.cmds_remaining, 0, "callbacks after all commands");
            let dev = d.device;
            self.tenants[dev] -= 1;
            if !self.available.contains(&dev) {
                self.available.push(dev);
            }
            if self.tenants[dev] == 0 {
                self.est_free[dev] = self.now;
            }
            self.comp_finish[comp] = self.now;
            self.comp_active_disp[comp] = None;
            self.comps_done += 1;
        }
    }

    /// Add a ready, released component to the rank-sorted (descending)
    /// frontier. Binary-search insertion keeps the invariant in O(log F)
    /// compares + one shift, instead of the former full `sort_by` per
    /// callback (a named ROADMAP perf item for large merged DAGs). Equal
    /// ranks insert after existing entries, matching the stable sort the
    /// previous implementation used.
    fn enter_frontier(&mut self, comp: usize) {
        if self.comp_dispatched[comp] || self.frontier.contains(&comp) {
            return;
        }
        let rank = self.comp_rank[comp];
        let ranks = &self.comp_rank;
        let idx = self
            .frontier
            .partition_point(|&c| ranks[c].total_cmp(&rank).is_ge());
        self.frontier.insert(idx, comp);
    }

    // ------------------------------------------------------------- kernels

    /// Per-run speed multipliers (relative to solo execution) per device.
    fn run_rates(&self) -> Vec<f64> {
        let mut rates = vec![1.0; self.runs.len()];
        for dev in 0..self.platform.devices.len() {
            let idxs: Vec<usize> = (0..self.runs.len())
                .filter(|&i| self.runs[i].device == dev)
                .collect();
            if idxs.is_empty() {
                continue;
            }
            let us: Vec<f64> = idxs.iter().map(|&i| self.runs[i].occupancy).collect();
            let speeds = contention::shared_speeds_with(&us, self.cfg.contention_efficiency);
            for (j, &i) in idxs.iter().enumerate() {
                rates[i] = speeds[j] / us[j];
            }
        }
        rates
    }

    fn next_kernel_completion(&self, rates: &[f64]) -> Option<f64> {
        self.runs
            .iter()
            .zip(rates)
            .map(|(r, &rate)| self.now + r.remaining / rate)
            .min_by(|a, b| a.total_cmp(b))
    }

    // ------------------------------------------------------------ main loop

    fn run(mut self) -> Result<SimResult> {
        let total = self.partition.components.len();
        // Withheld components (request not yet arrived) wake via events.
        for c in 0..total {
            if self.ext_preds_left[c] == 0 && self.release[c] > 0.0 {
                self.push_ev(self.release[c], EvKind::Release { comp: c });
            }
        }
        let mut events = 0usize;
        while self.comps_done < total {
            events += 1;
            if events > self.cfg.max_events {
                return Err(Error::Sched(format!(
                    "simulation exceeded {} events (deadlock?)",
                    self.cfg.max_events
                )));
            }
            self.scheduler_phase();
            self.issue_phase();
            if self.comps_done == total {
                break;
            }

            let rates = self.run_rates();
            let t_kernel = self.next_kernel_completion(&rates);
            let t_heap = self.heap.peek().map(|Reverse(e)| e.t);
            let t_next = match (t_kernel, t_heap) {
                (Some(a), Some(b)) => a.min(b),
                (Some(a), None) => a,
                (None, Some(b)) => b,
                (None, None) => {
                    return Err(Error::Sched(
                        "simulation stalled: no events, no running kernels".into(),
                    ))
                }
            };
            debug_assert!(t_next >= self.now - EPS, "time went backwards");
            let dt = (t_next - self.now).max(0.0);

            // Advance all running kernels by dt at their current rates.
            for (r, &rate) in self.runs.iter_mut().zip(&rates) {
                r.remaining -= dt * rate;
            }
            self.now = t_next;

            // Retire kernels that finished exactly now.
            let mut finished: Vec<usize> = (0..self.runs.len())
                .filter(|&i| self.runs[i].remaining <= 1e-9)
                .collect();
            finished.sort_unstable_by(|a, b| b.cmp(a));
            for i in finished {
                let r = self.runs.swap_remove(i);
                self.kernel_frac[r.kernel] = 1.0;
                let name = &self.dag.kernels[r.kernel].name;
                self.trace.push(Span {
                    label: format!("{name}{}", r.kernel),
                    lane: Lane::Device {
                        dev: r.device,
                        slot: r.queue,
                    },
                    start: r.started,
                    end: self.now,
                    cmd: Some(r.cmd),
                    kernel: Some(r.kernel),
                });
                self.command_done(r.disp, r.cmd);
            }

            // Handle all heap events due now.
            while let Some(Reverse(e)) = self.heap.peek() {
                if e.t > self.now + EPS {
                    break;
                }
                let Reverse(e) = self.heap.pop().unwrap();
                match e.kind {
                    EvKind::DispatchReady(_) => { /* issue phase picks it up */ }
                    EvKind::TransferDone { disp, cmd } => self.command_done(disp, cmd),
                    EvKind::CopyDone { engine } => {
                        let (di, cmd) = self.copy_engines[engine]
                            .current
                            .take()
                            .expect("engine busy");
                        self.command_done(di, cmd);
                        self.pump_copy_engine(engine);
                    }
                    EvKind::Callback { disp, kernel } => self.handle_callback(disp, kernel),
                    EvKind::Release { comp } => {
                        if self.ext_preds_left[comp] == 0 {
                            self.enter_frontier(comp);
                        }
                    }
                }
            }
        }

        Ok(SimResult {
            makespan: self.last_cmd_done,
            trace: self.trace,
            policy: self.policy.name().to_string(),
            component_finish: self.comp_finish,
            component_device: self.comp_device,
            preemptions: self.preemptions,
        })
    }
}

