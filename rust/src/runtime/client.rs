//! PJRT client wrapper: compile-once executable cache + typed execute.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`). The build
//! environment carries no PJRT bindings crate, so the `xla` API surface is
//! satisfied by the in-crate stand-in ([`super::backend`], aliased below);
//! swapping in real bindings changes only that alias — every call site and
//! the thread-safety contract stay identical.

use super::backend as xla;
use super::manifest::Manifest;
use crate::error::{Error, Result};
use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A compiled executable, shareable across the executor's queue threads.
///
/// SAFETY: `PjRtLoadedExecutable` wraps a C++ `xla::PjRtLoadedExecutable`,
/// whose `Execute` is documented thread-safe; the wrapper holds an owning
/// pointer freed on drop. We never mutate it after compilation, and `Shared`
/// keeps exactly one owner via `Arc`.
pub struct Shared(xla::PjRtLoadedExecutable);
unsafe impl Send for Shared {}
unsafe impl Sync for Shared {}

/// The L3-side runtime: one PJRT CPU client + a name→executable cache.
///
/// The cache is **warm across serving batches**: one `Runtime` serves every
/// batch of a `pyschedcl serve --mode real` run, so an artifact is lowered
/// and compiled exactly once per process, on the first batch whose workload
/// needs it. The hit/miss counters ([`Runtime::cache_stats`]) let the
/// serving report attribute first-vs-warm batch latency to compilation.
pub struct Runtime {
    client: Mutex<xla::PjRtClient>,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Shared>>>,
    /// Artifacts currently being lowered+compiled by some thread. Keeps
    /// compilation exactly-once per artifact *without* holding the cache
    /// lock across the compile, so warm hits never stall behind a cold
    /// compile on the serving hot path.
    in_flight: Mutex<HashSet<String>>,
    in_flight_cv: Condvar,
    /// [`Runtime::load`] calls served from `cache`.
    cache_hits: AtomicUsize,
    /// Artifacts actually lowered + compiled (one per distinct artifact;
    /// threads that waited on another thread's compile count as hits).
    cache_misses: AtomicUsize,
}

// SAFETY: PjRtClient wraps xla::PjRtClient (thread-safe in C++); all rust
// calls go through the Mutex anyway.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

fn xerr(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

impl Runtime {
    /// Create a runtime over the artifact directory (compiles lazily).
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Runtime {
            client: Mutex::new(client),
            manifest,
            cache: Mutex::new(HashMap::new()),
            in_flight: Mutex::new(HashSet::new()),
            in_flight_cv: Condvar::new(),
            cache_hits: AtomicUsize::new(0),
            cache_misses: AtomicUsize::new(0),
        })
    }

    /// `(hits, misses)` of the executable cache since construction.
    /// Monotone counters — serving paths snapshot before/after a run and
    /// report the delta.
    pub fn cache_stats(&self) -> (usize, usize) {
        (
            self.cache_hits.load(Ordering::Relaxed),
            self.cache_misses.load(Ordering::Relaxed),
        )
    }

    /// Platform string of the backing PJRT client (e.g. "cpu").
    pub fn platform_name(&self) -> String {
        self.client.lock().unwrap().platform_name()
    }

    /// Fetch (compiling on first use) the executable for `name`.
    ///
    /// Concurrent first loads of one artifact from the executor's queue
    /// threads must not each lower and compile a duplicate (which would
    /// also make the miss counter load-dependent) — yet a cold compile
    /// must not stall warm hits of *other* artifacts. So the cache lock is
    /// only ever held briefly: the first loader marks the artifact
    /// in-flight and compiles outside the lock; rivals wait on the condvar
    /// and then take the published executable as an ordinary hit.
    pub fn load(&self, name: &str) -> Result<Arc<Shared>> {
        loop {
            if let Some(exe) = self.cache.lock().unwrap().get(name) {
                self.cache_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(exe.clone());
            }
            let mut in_flight = self.in_flight.lock().unwrap();
            // Re-check under the in-flight lock: the compiler publishes to
            // the cache *before* clearing the marker, so a missing entry
            // plus a clear marker really means nobody is compiling.
            if let Some(exe) = self.cache.lock().unwrap().get(name) {
                self.cache_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(exe.clone());
            }
            if in_flight.insert(name.to_string()) {
                break; // this thread compiles
            }
            // Another thread is compiling this artifact: wait and retry.
            let _waited = self.in_flight_cv.wait(in_flight).unwrap();
        }
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
        let built = self.compile_artifact(name);
        if let Ok(shared) = &built {
            self.cache
                .lock()
                .unwrap()
                .insert(name.to_string(), shared.clone());
        }
        self.in_flight.lock().unwrap().remove(name);
        self.in_flight_cv.notify_all();
        built
    }

    /// Lower the HLO text and compile it — the cold path of [`Runtime::load`].
    fn compile_artifact(&self, name: &str) -> Result<Arc<Shared>> {
        let path = self.manifest.path_of(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )
        .map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = {
            let client = self.client.lock().unwrap();
            client.compile(&comp).map_err(xerr)?
        };
        Ok(Arc::new(Shared(exe)))
    }

    /// Eagerly compile every artifact (used by the serving-style example to
    /// move compilation off the request path).
    pub fn warmup(&self) -> Result<usize> {
        let names: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }

    /// Execute artifact `name` on f32 tensors (shape-checked against the
    /// manifest). Returns the flattened outputs.
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let meta = self.manifest.get(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            )));
        }
        let exe = self.load(name)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&meta.inputs) {
            let want: usize = shape.iter().product();
            if data.len() != want {
                return Err(Error::Runtime(format!(
                    "{name}: input length {} != shape {:?}",
                    data.len(),
                    shape
                )));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims).map_err(xerr)?;
            literals.push(lit);
        }
        let result = exe.0.execute::<xla::Literal>(&literals).map_err(xerr)?;
        let first = result[0][0].to_literal_sync().map_err(xerr)?;
        // aot.py lowers with return_tuple=True: unpack the tuple elements.
        let elems = first.to_tuple().map_err(xerr)?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<f32>().map_err(xerr)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::new(&dir).ok()
    }

    fn naive_gemm(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
        let mut c = vec![0f32; n * n];
        for i in 0..n {
            for k in 0..n {
                let aik = a[i * n + k];
                for j in 0..n {
                    c[i * n + j] += aik * b[k * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_reference() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let n = 32;
        let a: Vec<f32> = (0..n * n).map(|i| ((i * 37 % 23) as f32 - 11.0) / 7.0).collect();
        let b: Vec<f32> = (0..n * n).map(|i| ((i * 53 % 19) as f32 - 9.0) / 5.0).collect();
        let out = rt.execute_f32("gemm_b32", &[&a, &b]).unwrap();
        let want = naive_gemm(&a, &b, n);
        assert_eq!(out.len(), 1);
        for (x, y) in out[0].iter().zip(&want) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let Some(rt) = runtime() else {
            return;
        };
        let n = 32;
        let x: Vec<f32> = (0..n * n).map(|i| ((i % 13) as f32) / 3.0).collect();
        let out = rt.execute_f32("softmax_b32", &[&x]).unwrap();
        for row in out[0].chunks(n) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let Some(rt) = runtime() else {
            return;
        };
        let n = 32;
        let x: Vec<f32> = (0..(n * n) as u32).map(|i| i as f32).collect();
        let t = rt.execute_f32("transpose_b32", &[&x]).unwrap();
        let tt = rt.execute_f32("transpose_b32", &[&t[0]]).unwrap();
        assert_eq!(tt[0], x);
        assert_eq!(t[0][1], x[n]); // (0,1) of X^T == (1,0) of X
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(rt) = runtime() else {
            return;
        };
        let bad = vec![0f32; 7];
        assert!(rt.execute_f32("gemm_b32", &[&bad, &bad]).is_err());
        let ok = vec![0f32; 32 * 32];
        assert!(rt.execute_f32("gemm_b32", &[&ok]).is_err());
    }

    #[test]
    fn executables_are_cached() {
        let Some(rt) = runtime() else {
            return;
        };
        let (h0, m0) = rt.cache_stats();
        assert_eq!((h0, m0), (0, 0));
        let a = rt.load("gemm_b32").unwrap();
        let b = rt.load("gemm_b32").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let (h1, m1) = rt.cache_stats();
        assert_eq!((h1, m1), (1, 1), "second load must hit the cache");
    }

    #[test]
    fn cache_entries_do_not_alias_across_artifacts() {
        let Some(rt) = runtime() else {
            return;
        };
        // Distinct artifact names (different workload sizes) must compile
        // and cache independently — never serve one for the other.
        let a = rt.load("gemm_b32").unwrap();
        let b = rt.load("gemm_b64").unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        let (hits, misses) = rt.cache_stats();
        assert_eq!(misses, 2, "each artifact is its own cache entry");
        assert_eq!(hits, 0);
    }
}
