//! Offline PJRT stand-in.
//!
//! The build environment has no `xla`/PJRT bindings crate, so this module
//! provides the exact API surface [`super::client`] needs behind the same
//! `xla::` names, backed by a pure-Rust reference interpreter for the AOT
//! artifact inventory (gemm / softmax / transpose / vadd / vsin and the
//! fused attention head). The interpreter keys on the artifact file name
//! (`gemm_b256.hlo.txt` → op `gemm`); shapes come from the literals built
//! against the manifest, so `execute_f32`'s shape checks still apply.
//!
//! Numerics match `python/compile/kernels/ref.py`: plain f32 matmul, row-wise
//! stable softmax, element-wise sin/add — which is what the fused `head`
//! artifact composes, so the executor's composed-vs-fused cross-checks hold.
//! Swapping in real PJRT bindings means deleting this module and pointing
//! `client.rs` back at the external crate; the call sites do not change.

use std::fmt;
use std::path::Path;

/// Backend error (mirrors `xla::Error`'s `to_string` usage).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

/// Element types `Literal::to_vec` can produce (only f32 is used here).
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// A host literal: an f32 tensor or a tuple of literals (AOT entry points
/// lower with `return_tuple=True`).
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    value: Value,
}

#[derive(Debug, Clone)]
enum Value {
    F32(Vec<f32>),
    Tuple(Vec<Literal>),
}

impl Literal {
    /// A rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            value: Value::F32(data.to_vec()),
        }
    }

    fn tuple(elems: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![elems.len() as i64],
            value: Value::Tuple(elems),
        }
    }

    fn f32s(&self) -> Result<&[f32], Error> {
        match &self.value {
            Value::F32(v) => Ok(v),
            Value::Tuple(_) => Err(err("expected a dense literal, found a tuple")),
        }
    }

    /// Reinterpret the literal under new dimensions (element count checked).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        let have = self.f32s()?.len() as i64;
        if want != have {
            return Err(err(format!(
                "reshape to {dims:?} ({want} elems) from {have} elems"
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            value: self.value.clone(),
        })
    }

    /// Unpack a tuple literal; a dense literal unpacks to itself.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self.value {
            Value::Tuple(elems) => Ok(elems),
            Value::F32(_) => Ok(vec![self]),
        }
    }

    /// Flatten to a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Ok(self.f32s()?.iter().map(|&v| T::from_f32(v)).collect())
    }

    fn dims2(&self) -> Result<(usize, usize), Error> {
        match self.dims[..] {
            [r, c] => Ok((r as usize, c as usize)),
            _ => Err(err(format!("expected a 2-D literal, dims {:?}", self.dims))),
        }
    }
}

/// Parsed artifact handle: the op name recovered from the file stem.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    op: String,
}

impl HloModuleProto {
    /// "Parse" an HLO text file: the file must exist (same failure mode as
    /// the real text parser); the op is the stem prefix before `_`.
    pub fn from_text_file(path: &str) -> Result<Self, Error> {
        std::fs::read_to_string(path)
            .map_err(|e| err(format!("cannot read HLO text {path}: {e}")))?;
        let stem = Path::new(path)
            .file_name()
            .and_then(|s| s.to_str())
            .unwrap_or(path);
        let op = stem
            .split(['_', '.'])
            .next()
            .unwrap_or(stem)
            .to_string();
        Ok(HloModuleProto { op })
    }
}

/// A computation awaiting compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    op: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            op: proto.op.clone(),
        }
    }
}

/// The "client": op dispatch table for the reference interpreter.
#[derive(Debug, Default)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-interp".to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        if !matches!(
            comp.op.as_str(),
            "gemm" | "matmul" | "softmax" | "transpose" | "vadd" | "vsin" | "head"
        ) {
            return Err(err(format!("unsupported artifact op '{}'", comp.op)));
        }
        Ok(PjRtLoadedExecutable { op: comp.op.clone() })
    }
}

/// A device-resident result buffer.
#[derive(Debug, Clone)]
pub struct PjRtBuffer(Literal);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Ok(self.0.clone())
    }
}

/// A compiled executable: interprets its op on the host.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    op: String,
}

impl PjRtLoadedExecutable {
    /// Execute over the input literals. Returns the PJRT shape
    /// `[replica][output]`, with one tuple buffer per replica.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        let lits: Vec<&Literal> = args.iter().map(|a| a.borrow()).collect();
        let outputs = interpret(&self.op, &lits)?;
        Ok(vec![vec![PjRtBuffer(Literal::tuple(outputs))]])
    }
}

// ------------------------------------------------------------- interpreter

fn arity(op: &str, args: &[&Literal], want: usize) -> Result<(), Error> {
    if args.len() != want {
        return Err(err(format!("{op}: expected {want} inputs, got {}", args.len())));
    }
    Ok(())
}

fn matmul(a: &Literal, b: &Literal) -> Result<Literal, Error> {
    let (m, k) = a.dims2()?;
    let (k2, n) = b.dims2()?;
    if k != k2 {
        return Err(err(format!("gemm: inner dims {k} vs {k2}")));
    }
    let av = a.f32s()?;
    let bv = b.f32s()?;
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let aik = av[i * k + kk];
            for j in 0..n {
                c[i * n + j] += aik * bv[kk * n + j];
            }
        }
    }
    Ok(Literal {
        dims: vec![m as i64, n as i64],
        value: Value::F32(c),
    })
}

fn transpose(x: &Literal) -> Result<Literal, Error> {
    let (r, c) = x.dims2()?;
    let xv = x.f32s()?;
    let mut t = vec![0f32; r * c];
    for i in 0..r {
        for j in 0..c {
            t[j * r + i] = xv[i * c + j];
        }
    }
    Ok(Literal {
        dims: vec![c as i64, r as i64],
        value: Value::F32(t),
    })
}

fn softmax(x: &Literal) -> Result<Literal, Error> {
    let (r, c) = x.dims2()?;
    let xv = x.f32s()?;
    let mut out = vec![0f32; r * c];
    for (row_in, row_out) in xv.chunks(c).zip(out.chunks_mut(c)) {
        let m = row_in.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for (o, &v) in row_out.iter_mut().zip(row_in) {
            *o = (v - m).exp();
            sum += *o;
        }
        for o in row_out.iter_mut() {
            *o /= sum;
        }
    }
    Ok(Literal {
        dims: vec![r as i64, c as i64],
        value: Value::F32(out),
    })
}

fn elementwise(x: &Literal, f: impl Fn(f32) -> f32) -> Result<Literal, Error> {
    Ok(Literal {
        dims: x.dims.clone(),
        value: Value::F32(x.f32s()?.iter().map(|&v| f(v)).collect()),
    })
}

fn interpret(op: &str, args: &[&Literal]) -> Result<Vec<Literal>, Error> {
    match op {
        "gemm" | "matmul" => {
            arity(op, args, 2)?;
            Ok(vec![matmul(args[0], args[1])?])
        }
        "transpose" => {
            arity(op, args, 1)?;
            Ok(vec![transpose(args[0])?])
        }
        "softmax" => {
            arity(op, args, 1)?;
            Ok(vec![softmax(args[0])?])
        }
        "vsin" => {
            arity(op, args, 1)?;
            Ok(vec![elementwise(args[0], f32::sin)?])
        }
        "vadd" => {
            arity(op, args, 2)?;
            let (a, b) = (args[0].f32s()?, args[1].f32s()?);
            if a.len() != b.len() {
                return Err(err(format!("vadd: lengths {} vs {}", a.len(), b.len())));
            }
            Ok(vec![Literal {
                dims: args[0].dims.clone(),
                value: Value::F32(a.iter().zip(b).map(|(x, y)| x + y).collect()),
            }])
        }
        "head" => {
            // The paper's 8-kernel attention head, fused (see model.head_fn):
            // Q=XWq, K=XWk, V=XWv, A=Q·Kᵀ, B=softmax(A), C=B·V, Z=C·Wo.
            arity(op, args, 5)?;
            let (x, wq, wk, wv, wo) = (args[0], args[1], args[2], args[3], args[4]);
            let q = matmul(x, wq)?;
            let k = matmul(x, wk)?;
            let v = matmul(x, wv)?;
            let kt = transpose(&k)?;
            let a = matmul(&q, &kt)?;
            let b = softmax(&a)?;
            let c = matmul(&b, &v)?;
            Ok(vec![matmul(&c, wo)?])
        }
        other => Err(err(format!("unsupported artifact op '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lit2(data: &[f32], r: i64, c: i64) -> Literal {
        Literal::vec1(data).reshape(&[r, c]).unwrap()
    }

    #[test]
    fn gemm_matches_naive() {
        let a = lit2(&[1.0, 2.0, 3.0, 4.0], 2, 2);
        let b = lit2(&[5.0, 6.0, 7.0, 8.0], 2, 2);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.to_vec::<f32>().unwrap(), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_roundtrips() {
        let x = lit2(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let t = transpose(&x).unwrap();
        assert_eq!(t.dims, vec![3, 2]);
        let tt = transpose(&t).unwrap();
        assert_eq!(tt.to_vec::<f32>().unwrap(), x.to_vec::<f32>().unwrap());
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = lit2(&[0.0, 1.0, 2.0, -1.0, 0.5, 3.0], 2, 3);
        let s = softmax(&x).unwrap();
        for row in s.to_vec::<f32>().unwrap().chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn head_composes_the_kernel_chain() {
        let n = 4usize;
        let m: Vec<f32> = (0..n * n).map(|i| ((i * 7 % 5) as f32 - 2.0) / 3.0).collect();
        let x = lit2(&m, n as i64, n as i64);
        let composed = {
            let q = matmul(&x, &x).unwrap();
            let k = matmul(&x, &x).unwrap();
            let v = matmul(&x, &x).unwrap();
            let kt = transpose(&k).unwrap();
            let a = matmul(&q, &kt).unwrap();
            let b = softmax(&a).unwrap();
            let c = matmul(&b, &v).unwrap();
            matmul(&c, &x).unwrap()
        };
        let fused = interpret("head", &[&x, &x, &x, &x, &x]).unwrap();
        assert_eq!(
            fused[0].to_vec::<f32>().unwrap(),
            composed.to_vec::<f32>().unwrap()
        );
    }

    #[test]
    fn reshape_checks_element_count() {
        let x = Literal::vec1(&[1.0, 2.0, 3.0]);
        assert!(x.reshape(&[2, 2]).is_err());
        assert!(x.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn unknown_op_rejected_at_compile() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation {
            op: "fft".to_string(),
        };
        assert!(client.compile(&comp).is_err());
    }
}
