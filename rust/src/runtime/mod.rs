//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute them
//! from the L3 hot path. Python never runs here — the HLO text was produced
//! once by `make artifacts` (`python/compile/aot.py`).
//!
//! * [`manifest`] — `artifacts/manifest.json` (names, files, shapes, flops).
//! * [`client`] — `PjRtClient::cpu()` wrapper with a compiled-executable
//!   cache, thread-safe for the multi-queue real executor.
//! * [`backend`] — offline PJRT stand-in: the `xla` API surface backed by a
//!   pure-Rust reference interpreter (no bindings crate in this build).

pub mod backend;
pub mod client;
pub mod manifest;

pub use client::Runtime;
pub use manifest::{ArtifactMeta, Manifest};
