//! The artifact manifest emitted by `python/compile/aot.py`.

use crate::error::{Error, Result};
use crate::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub op: String,
    /// Input tensor shapes, argument order.
    pub inputs: Vec<Vec<usize>>,
    /// Output tensor shapes.
    pub outputs: Vec<Vec<usize>>,
    pub flops: u64,
    pub bytes: u64,
    pub sha256: String,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        let root = Json::parse(&text)?;
        let mut artifacts = HashMap::new();
        let list = root
            .field("artifacts")?
            .as_arr()
            .ok_or_else(|| Error::Runtime("'artifacts' must be an array".into()))?;
        for a in list {
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
                Ok(a.get(key)
                    .and_then(|v| v.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .map(|s| {
                                s.as_arr()
                                    .map(|dims| {
                                        dims.iter()
                                            .filter_map(|d| d.as_usize())
                                            .collect::<Vec<_>>()
                                    })
                                    .unwrap_or_default()
                            })
                            .collect()
                    })
                    .unwrap_or_default())
            };
            let meta = ArtifactMeta {
                name: a
                    .field("name")?
                    .as_str()
                    .ok_or_else(|| Error::Runtime("artifact name".into()))?
                    .to_string(),
                file: a
                    .field("file")?
                    .as_str()
                    .ok_or_else(|| Error::Runtime("artifact file".into()))?
                    .to_string(),
                op: a
                    .get("op")
                    .and_then(|o| o.as_str())
                    .unwrap_or("")
                    .to_string(),
                inputs: shapes("inputs")?,
                outputs: shapes("outputs")?,
                flops: a.get("flops").and_then(|f| f.as_u64()).unwrap_or(0),
                bytes: a.get("bytes").and_then(|b| b.as_u64()).unwrap_or(0),
                sha256: a
                    .get("sha256")
                    .and_then(|s| s.as_str())
                    .unwrap_or("")
                    .to_string(),
            };
            artifacts.insert(meta.name.clone(), meta);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))
    }

    /// Absolute path of an artifact's HLO text.
    pub fn path_of(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }
}

/// Default artifact directory: `$PYSCHEDCL_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("PYSCHEDCL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_manifest_when_built() {
        let Some(m) = repo_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // The β sweep the experiments need must be present.
        for b in [64usize, 128, 256, 512] {
            for op in ["gemm", "softmax", "transpose", "head"] {
                let a = m.get(&format!("{op}_b{b}")).expect("artifact present");
                assert!(!a.inputs.is_empty());
                assert!(m.path_of(&a.name).unwrap().exists());
            }
        }
    }

    #[test]
    fn gemm_shapes_square() {
        let Some(m) = repo_artifacts() else {
            return;
        };
        let g = m.get("gemm_b64").unwrap();
        assert_eq!(g.inputs, vec![vec![64, 64], vec![64, 64]]);
        assert_eq!(g.outputs, vec![vec![64, 64]]);
        assert_eq!(g.flops, 2 * 64 * 64 * 64);
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(m) = repo_artifacts() else {
            return;
        };
        assert!(m.get("nonexistent_b7").is_err());
    }
}
