//! `pyschedcl` — the leader binary.
//!
//! Subcommands (offline environment: CLI parsing is hand-rolled):
//!
//! ```text
//! pyschedcl inspect   <spec.json>                 DAG + partition summary
//! pyschedcl simulate  <spec.json> [--policy P]    simulate a spec file
//! pyschedcl run       <spec.json> [--artifacts D] real PJRT execution
//! pyschedcl motivation [--beta 256]               Figs. 4/5
//! pyschedcl expt1 [--hmax 16] [--beta 256]        Fig. 11
//! pyschedcl expt2 [--betas 64,128,256,512]        Fig. 12(a)
//! pyschedcl expt3 [--betas 64,128,256,512]        Fig. 12(b)
//! pyschedcl gantt --policy P [--heads 16] [--beta 512]   Fig. 13
//! pyschedcl calibrate [--artifacts D] [--out F]   measure real kernel times
//! pyschedcl autotune [--heads 16] [--beta 256] [--strategy hill|exhaustive]
//! pyschedcl serve [--requests 32] [--arrival poisson|trace] [--trace F]
//!                 [--rate 2000] [--policy P] [--workload head|layer|mm2|...]
//!                 [--beta 64] [--heads 4] [--gpus 1] [--cpus 1]
//!                 [--tenancy 4] [--batch-window-ms 2] [--seed 42]
//!                 [--deadline-ms F] [--deadline-tight-ms F]
//!                 [--deadline-tight-every K]
//!                 [--mode sim|real] [--pacing closed|open] [--prewarm]
//!                 [--admission-laxity on|off]
//!                 [--autoscale-target F] [--autoscale-max-gpus N]
//!                 [--streaming] [--window 512] [--outcomes-jsonl OUT]
//!                 [--faults PLAN.json] [--retry-budget N]
//!                 [--shed-policy lowest-priority|latest-deadline]
//!                 [--shards N] [--spill-threshold 64] [--shard-slo F]
//!                 [--json OUT]                      multi-DAG serving
//! pyschedcl bench-check --baseline F --current F [--tolerance 0.15]
//!                 [--update] [--validate]       CI bench-regression gate
//! pyschedcl fuzz [--seeds N] [--start S] [--orderings K] [--seed X]
//!                 [--shrink] [--corpus DIR] [--report-dir DIR] [--verbose]
//!                 deterministic scheduler-core concurrency fuzzer
//! ```
//!
//! Deadline-aware serving: `--policy edf` schedules earliest absolute
//! deadline first with preemption; `--deadline-ms` gives every request a
//! latency budget, and `--deadline-tight-ms`/`--deadline-tight-every` mark
//! every K-th request as a tight-deadline, priority-1 tenant. Requests
//! whose laxity is already negative at arrival are rejected at admission
//! (`--admission-laxity off` disables). `--autoscale-target F` (sim only)
//! loops `serve_sim` over `Platform::scaled` GPU counts (up to
//! `--autoscale-max-gpus`, default 8) until the deadline-miss rate is ≤ F,
//! reports the chosen scale, and serves the comparison there — the
//! SLO-aware capacity-planning experiment. On the real path `--pacing open`
//! makes the serving loop sleep until each batch's nominal release instant
//! (open-loop latency measurement) and `--prewarm` compiles every AOT
//! artifact before the epoch.
//!
//! Serving hot path (PR 4): both serve modes reuse per-signature app
//! templates and pre-merged (signature, batch-size) blocks
//! ([`pyschedcl::serve::TemplateCache`]); the report prints the cache's
//! hit/miss line and the BENCH JSON carries `template_cache_hits/misses`.
//! The 10k-request scale proof lives in `benches/serve_scale.rs`
//! (`cargo bench --bench serve_scale`), gated in CI via `bench-check`
//! against `ci/bench_baselines/BENCH_serve_scale.json`.
//!
//! Always-on serving (PR 6): `--streaming` runs the same stream through the
//! long-lived bounded-memory server — admission interleaves with execution
//! under a `--window N` live-request bound, completed requests are retired,
//! and `--outcomes-jsonl OUT` streams one JSON object per completion
//! instead of accumulating a report vector. The 1M-request soak proof lives
//! in `benches/serve_soak.rs`, gated in CI against
//! `ci/bench_baselines/BENCH_serve_soak.json`.
//!
//! Unified serve core (PR 7): every serving mode routes through
//! [`pyschedcl::serve::serve_core`] over a `ServeBackend` — `--streaming`
//! composes with `--mode real` ([`pyschedcl::serve::serve_real_stream`]):
//! the always-on admission/backpressure loop drives real PJRT execution
//! with `--pacing open|closed`, bounded live state, and the
//! `BENCH_serve_real_stream.json` artifact via `--json` (gated in CI
//! against `ci/bench_baselines/BENCH_serve_real_stream.json`). Batch modes
//! are the same core at window 0.
//!
//! Fault-injected serving (PR 9): `--faults PLAN.json` installs a seeded
//! device crash/wedge/slowdown plan into the always-on server (sim and
//! real): crashed devices leave the scheduler, their work retries on the
//! survivors under the plan's retry budget and exponential backoff, and
//! queued work whose deadline can no longer be met is shed under
//! `--shed-policy`. `--retry-budget N` overrides the plan's budget. The
//! report's `served + rejected + shed == offered` accounting and the
//! chaos proof live in `benches/serve_chaos.rs`, gated in CI against
//! `ci/bench_baselines/BENCH_serve_chaos.json`.
//!
//! Sharded serving (PR 10): `--shards N` (streaming only) partitions the
//! platform into N equal replica shards — each with its own scheduler
//! state, backend, and template/executable caches — behind the
//! signature-affinity router ([`pyschedcl::serve::Router`]): requests hash
//! by workload signature to an affine shard (cache locality) and spill to
//! the less-loaded of two choices only when the affine queue depth exceeds
//! `--spill-threshold`. `--shard-slo F` arms the SLO-driven rebalancer
//! (halves the effective spill threshold while the observed miss rate
//! exceeds F). Shards execute concurrently on scoped threads; per-shard
//! reports merge bin-wise into one conserved report. `--autoscale-target`
//! now binary-searches the GPU axis with a per-scale report cache instead
//! of a linear scan. The 4→64-GPU scaling proof lives in
//! `benches/serve_shard.rs`, gated in CI against
//! `ci/bench_baselines/BENCH_serve_shard.json`.

use pyschedcl::cost::{CalibratedCost, CostModel, PaperCost};
use pyschedcl::error::{Error, Result};
use pyschedcl::exec::execute_dag;
use pyschedcl::fault::{FaultPlan, ShedPolicy};
use pyschedcl::graph::Partition;
use pyschedcl::json::Json;
use pyschedcl::platform::{DeviceType, Platform};
use pyschedcl::report::experiments as expts;
use pyschedcl::report::{
    check_bench, format_gate, format_gate_markdown, format_real_summary,
    format_serve_comparison, format_sharded_summary, format_stream_summary, load_baseline,
    peak_rss_mb, serve_bench_json, serve_real_stream_json, serve_shard_json, serve_soak_json,
    update_baseline,
};
use pyschedcl::runtime::{manifest::default_artifact_dir, Runtime};
use pyschedcl::sched::{Clustering, Eager, Edf, Heft, LeastLoaded, Policy};
use pyschedcl::serve::{
    autoscale_search, parse_rate, poisson_arrivals, serve_real, serve_real_stream,
    serve_sequential, serve_sharded_real_stream, serve_sharded_stream, serve_sim, serve_stream,
    trace_arrivals, JsonlSink, NullSink, Pacing, PlatformShape, ServeConfig, ServeRequest,
    ShardSpec, StreamingConfig, Workload,
};
use pyschedcl::sim::{simulate, SimConfig};
use pyschedcl::spec::parse_spec;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

/// Tiny flag parser: positionals + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // Bare boolean flags (`--prewarm --json X`): the next token
                // being another flag means this one carries no value.
                let val = match it.peek() {
                    Some(next) if !next.starts_with("--") => it.next().cloned().unwrap(),
                    _ => "true".into(),
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn need_positional(&self, idx: usize, what: &str) -> Result<&str> {
        self.positional
            .get(idx)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::Io(format!("missing argument: {what}")))
    }
}

fn policy_by_name(name: &str) -> Result<Box<dyn Policy>> {
    match name {
        "clustering" => Ok(Box::new(Clustering)),
        "eager" => Ok(Box::new(Eager)),
        "heft" => Ok(Box::new(Heft)),
        "least-loaded" => Ok(Box::new(LeastLoaded)),
        "edf" => Ok(Box::new(Edf)),
        other => Err(Error::Sched(format!("unknown policy '{other}'"))),
    }
}

fn load_spec(path: &str) -> Result<pyschedcl::spec::ApplicationSpec> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Io(format!("cannot read {path}: {e}")))?;
    parse_spec(&text)
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let spec = load_spec(args.need_positional(0, "spec.json")?)?;
    println!(
        "kernels={} buffers={} edges={} components={}",
        spec.dag.num_kernels(),
        spec.dag.buffers.len(),
        spec.dag.buffer_edges.len(),
        spec.partition.components.len()
    );
    for c in &spec.partition.components {
        let front = spec.partition.front(&spec.dag, c.id);
        let end = spec.partition.end(&spec.dag, c.id);
        let inner = spec.partition.inner(&spec.dag, c.id);
        println!(
            "  T{} dev={} kernels={:?} FRONT={front:?} END={end:?} IN={inner:?}",
            c.id, c.dev, c.kernels
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let spec = load_spec(args.need_positional(0, "spec.json")?)?;
    let mut policy = policy_by_name(args.get("policy").unwrap_or("clustering"))?;
    let q_gpu = *spec.queues.get(&DeviceType::Gpu).unwrap_or(&1);
    let q_cpu = *spec.queues.get(&DeviceType::Cpu).unwrap_or(&1);
    let platform = Platform::paper_testbed(q_gpu, q_cpu);
    let partition = if policy.name() == "clustering" {
        spec.partition.clone()
    } else {
        Partition::singletons(&spec.dag)
    };
    let r = simulate(
        &spec.dag,
        &partition,
        &platform,
        &PaperCost,
        policy.as_mut(),
        &SimConfig::default(),
    )?;
    println!(
        "policy={} makespan={:.3} ms  gpu_overlap={:.3} ms  copy_overlap={:.3} ms",
        r.policy,
        r.makespan * 1e3,
        r.trace.device_overlap(0) * 1e3,
        r.trace.copy_compute_overlap(0) * 1e3
    );
    if args.get("gantt").is_some() {
        print!("{}", r.trace.ascii(100));
    }
    Ok(())
}

/// Deterministic pseudo-random input generator (xorshift64*).
fn seeded_input(seed: u64, len: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(2685821657736338717).max(1);
    (0..len)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            let v = s.wrapping_mul(2685821657736338717);
            ((v >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect()
}

fn cmd_run(args: &Args) -> Result<()> {
    let spec = load_spec(args.need_positional(0, "spec.json")?)?;
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let runtime = Arc::new(Runtime::new(&dir)?);
    println!("pjrt platform = {}", runtime.platform_name());
    let mut policy = policy_by_name(args.get("policy").unwrap_or("clustering"))?;
    let q_gpu = *spec.queues.get(&DeviceType::Gpu).unwrap_or(&1);
    let q_cpu = *spec.queues.get(&DeviceType::Cpu).unwrap_or(&1);
    let platform = Platform::paper_testbed(q_gpu.max(1), q_cpu.max(1));

    // Seed every isolated input buffer with deterministic data.
    let mut inputs = HashMap::new();
    for b in &spec.dag.buffers {
        let is_input = spec.dag.kernels[b.kernel].inputs.contains(&b.id);
        if is_input && spec.dag.buffer_pred(b.id).is_none() {
            inputs.insert(
                b.id,
                seeded_input(b.id as u64 + 1, (b.size_bytes / 4) as usize),
            );
        }
    }
    let report = execute_dag(
        &spec.dag,
        &spec.partition,
        &platform,
        &PaperCost,
        policy.as_mut(),
        &runtime,
        &inputs,
    )?;
    println!("makespan = {:.3} ms (wall)", report.makespan * 1e3);
    for k in spec.dag.sink_kernels() {
        for &b in &spec.dag.kernels[k].outputs {
            if let Some(data) = report.store.host(b) {
                let sum: f32 = data.iter().sum();
                println!(
                    "  output buffer {b} (kernel {k}): {} elems, sum={sum:.4}",
                    data.len()
                );
            }
        }
    }
    Ok(())
}

fn cmd_motivation(args: &Args) -> Result<()> {
    let m = expts::motivation(args.u64_or("beta", 256))?;
    println!(
        "Figs. 4/5 — coarse (1 queue): {:.1} ms | fine (3 queues): {:.1} ms | speedup {:.3}x",
        m.coarse_ms, m.fine_ms, m.speedup
    );
    println!("paper: 105 ms -> 95 ms (~8%)");
    println!("\ncoarse:\n{}", m.coarse.trace.ascii(100));
    println!("fine:\n{}", m.fine.trace.ascii(100));
    Ok(())
}

fn parse_betas(args: &Args) -> Vec<u64> {
    args.get("betas")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|| vec![64, 128, 256, 512])
}

/// Measure real PJRT-CPU kernel times per artifact and persist a
/// [`CalibratedCost`] table. The GPU column is the CPU measurement divided
/// by the paper's published device ratio (DESIGN.md §Substitutions).
fn cmd_calibrate(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| dir.join("calibration.json"));
    let runtime = Runtime::new(&dir)?;
    let reps = args.usize_or("reps", 3);
    let mut table = CalibratedCost::default();
    let gpu = pyschedcl::platform::Device::gtx970(0, 1);
    let cpu = pyschedcl::platform::Device::i5_4690k(1, 1);
    let mut names: Vec<String> = runtime.manifest.artifacts.keys().cloned().collect();
    names.sort();
    for name in &names {
        let meta = runtime.manifest.get(name)?.clone();
        if meta.op == "head" {
            continue; // fused ablation target, not a DAG kernel
        }
        let inputs: Vec<Vec<f32>> = meta
            .inputs
            .iter()
            .map(|s| seeded_input(7, s.iter().product()))
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        runtime.execute_f32(name, &refs)?; // warm the executable cache
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            runtime.execute_f32(name, &refs)?;
        }
        let cpu_secs = t0.elapsed().as_secs_f64() / reps as f64;
        let node = kernel_node_for(&meta);
        let ratio = PaperCost.exec_time(&node, &cpu) / PaperCost.exec_time(&node, &gpu);
        table.insert(&node, &cpu, cpu_secs);
        table.insert(&node, &gpu, cpu_secs / ratio);
        println!("{name}: cpu {cpu_secs:.6}s (gpu scaled /{ratio:.1})");
    }
    table.save(&out)?;
    println!("wrote {}", out.display());
    Ok(())
}

fn kernel_node_for(meta: &pyschedcl::runtime::ArtifactMeta) -> pyschedcl::graph::KernelNode {
    let mut b = pyschedcl::graph::DagBuilder::new();
    let k = b.kernel(&meta.op, DeviceType::Gpu, meta.flops, meta.bytes);
    b.dag().kernels[k].clone()
}

/// `pyschedcl serve`: run a request stream through the multi-DAG serving
/// layer (sim by default, `--mode real` over PJRT) and print the
/// sequential-vs-concurrent comparison table. `--json PATH` additionally
/// writes the BENCH_serve.json perf artifact.
fn cmd_serve(args: &Args) -> Result<()> {
    let n = args.usize_or("requests", 32);
    let seed = args.u64_or("seed", 42);
    let beta = args.u64_or("beta", 64);
    let heads = args.usize_or("heads", 4);
    let h_cpu = args.usize_or("h-cpu", 0);
    // `--rate` is validated, not silently defaulted: garbage and
    // non-positive rates are typed admission errors (parse_rate).
    let rate = match args.get("rate") {
        Some(text) => parse_rate(text)?,
        None => 2000.0,
    };
    let workload = Workload::parse(args.get("workload").unwrap_or("head"), heads, beta, h_cpu)?;

    let arrivals = match args.get("arrival").unwrap_or("poisson") {
        "poisson" => poisson_arrivals(seed, n, rate)?,
        "trace" => {
            let path = args
                .get("trace")
                .ok_or_else(|| Error::Io("--arrival trace requires --trace FILE".into()))?;
            let text = std::fs::read_to_string(path)
                .map_err(|e| Error::Io(format!("cannot read {path}: {e}")))?;
            let t = trace_arrivals(&text)?;
            if t.len() < n {
                return Err(Error::Admission(format!(
                    "trace has {} arrivals, --requests {n}",
                    t.len()
                )));
            }
            t[..n].to_vec()
        }
        other => {
            return Err(Error::Io(format!(
                "unknown arrival process '{other}' (expected poisson|trace)"
            )))
        }
    };
    // Deadline shaping: a uniform budget for everyone, plus an optional
    // tight budget (and priority 1) for every K-th request — the stream
    // shape the EDF-vs-least-loaded comparison is about.
    let deadline_ms = args.get("deadline-ms").and_then(|v| v.parse::<f64>().ok());
    let tight_ms = args
        .get("deadline-tight-ms")
        .and_then(|v| v.parse::<f64>().ok());
    let tight_every = args.usize_or("deadline-tight-every", 4);
    let requests: Vec<ServeRequest> = arrivals
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let mut r = ServeRequest::new(i, t, workload.clone());
            r.deadline = deadline_ms.map(|d| d * 1e-3);
            if let Some(tight) = tight_ms {
                if tight_every > 0 && i % tight_every == 0 {
                    r.deadline = Some(tight * 1e-3);
                    r.priority = 1;
                }
            }
            r
        })
        .collect();

    let mut platform = Platform::scaled(
        args.usize_or("gpus", 1),
        args.usize_or("cpus", 1),
        args.usize_or("queues-gpu", 3),
        args.usize_or("queues-cpu", 1),
    );
    let pacing = match args.get("pacing").unwrap_or("closed") {
        "closed" => Pacing::Closed,
        "open" => Pacing::Open,
        other => {
            return Err(Error::Io(format!(
                "unknown pacing '{other}' (expected closed|open)"
            )))
        }
    };
    let laxity_admission = match args.get("admission-laxity").unwrap_or("on") {
        "on" => true,
        "off" => false,
        other => {
            return Err(Error::Io(format!(
                "unknown admission-laxity '{other}' (expected on|off)"
            )))
        }
    };
    // A bare `--prewarm` parses as the value "true".
    let prewarm = match args.get("prewarm") {
        None | Some("false") | Some("off") => false,
        Some("true") | Some("on") => true,
        Some(other) => {
            return Err(Error::Io(format!(
                "unknown prewarm '{other}' (expected on|off)"
            )))
        }
    };
    let cfg = ServeConfig {
        batch_window: args
            .get("batch-window-ms")
            .and_then(|v| v.parse::<f64>().ok())
            .unwrap_or(2.0)
            * 1e-3,
        tenancy: args.usize_or("tenancy", 4),
        pacing,
        laxity_admission,
        prewarm,
        sim: SimConfig::default(),
    };
    let policy_name = args.get("policy").unwrap_or("clustering");

    println!(
        "serving {n} × {} | arrival={} rate={rate}/s seed={seed} | {} gpu(s) {} cpu(s) \
         tenancy={} | policy={policy_name} pacing={}",
        workload.signature(),
        args.get("arrival").unwrap_or("poisson"),
        args.usize_or("gpus", 1),
        args.usize_or("cpus", 1),
        cfg.tenancy,
        cfg.pacing.as_str(),
    );

    // A bare `--streaming` parses as the value "true".
    let streaming = match args.get("streaming") {
        None | Some("false") | Some("off") => false,
        Some("true") | Some("on") => true,
        Some(other) => {
            return Err(Error::Io(format!(
                "unknown streaming '{other}' (expected on|off)"
            )))
        }
    };
    if !streaming
        && (args.get("faults").is_some()
            || args.get("retry-budget").is_some()
            || args.get("shed-policy").is_some())
    {
        return Err(Error::Io(
            "--faults/--retry-budget/--shed-policy drive the always-on server \
             (add --streaming)"
                .into(),
        ));
    }
    if !streaming
        && (args.get("shards").is_some()
            || args.get("spill-threshold").is_some()
            || args.get("shard-slo").is_some())
    {
        return Err(Error::Io(
            "--shards/--spill-threshold/--shard-slo partition the always-on server \
             (add --streaming)"
                .into(),
        ));
    }
    if streaming {
        if args.get("autoscale-target").is_some() {
            return Err(Error::Io(
                "--autoscale-target is a batch-mode experiment (drop --streaming)".into(),
            ));
        }
        // Chaos serving: a seeded fault plan, with CLI overrides for the
        // retry budget and the degradation policy.
        let faults = match args.get("faults") {
            Some(path) => {
                let mut plan = FaultPlan::from_file(path)?;
                if let Some(v) = args.get("retry-budget") {
                    plan.retry_budget = v.parse().map_err(|_| {
                        Error::Io(format!(
                            "invalid --retry-budget '{v}' (expected a non-negative integer)"
                        ))
                    })?;
                }
                if let Some(v) = args.get("shed-policy") {
                    plan.shed_policy = ShedPolicy::parse(v)?;
                }
                println!(
                    "fault plan: {} event(s), retry budget {}, shed policy {}",
                    plan.events.len(),
                    plan.retry_budget,
                    plan.shed_policy.name()
                );
                Some(plan)
            }
            None => {
                if args.get("retry-budget").is_some() || args.get("shed-policy").is_some() {
                    return Err(Error::Io(
                        "--retry-budget and --shed-policy tune a fault plan \
                         (add --faults PLAN.json)"
                            .into(),
                    ));
                }
                None
            }
        };
        let scfg = StreamingConfig {
            window: args.usize_or("window", 512),
            batch_window: cfg.batch_window,
            tenancy: cfg.tenancy,
            laxity_admission: cfg.laxity_admission,
            sim: SimConfig::default(),
            faults,
        };
        // Sharded multi-replica serving: N concurrent serve loops on
        // disjoint sub-platforms behind the signature-affinity router.
        // `--shards 1` (the default) stays on the unsharded paths below,
        // which the integration test pins byte-identical.
        let shards = args.usize_or("shards", 1);
        if shards > 1 {
            let shape = PlatformShape {
                gpus: args.usize_or("gpus", 1),
                cpus: args.usize_or("cpus", 1),
                queues_gpu: args.usize_or("queues-gpu", 3),
                queues_cpu: args.usize_or("queues-cpu", 1),
            };
            let slo_target = match args.get("shard-slo") {
                Some(t) => {
                    let v: f64 = t.parse().map_err(|_| {
                        Error::Io(format!(
                            "invalid --shard-slo '{t}' (expected a miss-rate fraction)"
                        ))
                    })?;
                    if !(0.0..=1.0).contains(&v) {
                        return Err(Error::Io(format!(
                            "--shard-slo {v} out of range (expected within [0, 1])"
                        )));
                    }
                    Some(v)
                }
                None => None,
            };
            let spec = ShardSpec {
                shards,
                spill_threshold: args.usize_or("spill-threshold", 64),
                slo_target,
                ..ShardSpec::default()
            };
            let factory = || policy_by_name(policy_name);
            let wall = std::time::Instant::now();
            let sharded = if args.get("mode") == Some("real") {
                let dir = args
                    .get("artifacts")
                    .map(PathBuf::from)
                    .unwrap_or_else(default_artifact_dir);
                let calibrated = CalibratedCost::load(&dir.join("calibration.json")).ok();
                let cost: &dyn CostModel = match &calibrated {
                    Some(c) => {
                        println!("cost model: calibrated ({}/calibration.json)", dir.display());
                        c
                    }
                    None => &PaperCost,
                };
                match args.get("outcomes-jsonl") {
                    Some(path) => {
                        let file = std::fs::File::create(path)
                            .map_err(|e| Error::Io(format!("cannot create {path}: {e}")))?;
                        let mut sink = JsonlSink::new(std::io::BufWriter::new(file));
                        let r = serve_sharded_real_stream(
                            requests,
                            &dir,
                            shape,
                            cost,
                            factory,
                            &scfg,
                            pacing,
                            prewarm,
                            seed,
                            &spec,
                            &mut sink,
                        )?;
                        println!("wrote per-request outcomes to {path}");
                        r
                    }
                    None => serve_sharded_real_stream(
                        requests,
                        &dir,
                        shape,
                        cost,
                        factory,
                        &scfg,
                        pacing,
                        prewarm,
                        seed,
                        &spec,
                        &mut NullSink,
                    )?,
                }
            } else {
                match args.get("outcomes-jsonl") {
                    Some(path) => {
                        let file = std::fs::File::create(path)
                            .map_err(|e| Error::Io(format!("cannot create {path}: {e}")))?;
                        let mut sink = JsonlSink::new(std::io::BufWriter::new(file));
                        let r = serve_sharded_stream(
                            requests,
                            shape,
                            &PaperCost,
                            factory,
                            &scfg,
                            &spec,
                            &mut sink,
                        )?;
                        println!("wrote per-request outcomes to {path}");
                        r
                    }
                    None => serve_sharded_stream(
                        requests,
                        shape,
                        &PaperCost,
                        factory,
                        &scfg,
                        &spec,
                        &mut NullSink,
                    )?,
                }
            };
            let wall_seconds = wall.elapsed().as_secs_f64();
            print!("{}", format_sharded_summary(&sharded));
            if let Some(path) = args.get("json") {
                let json = serve_shard_json(&sharded, wall_seconds);
                std::fs::write(path, json.to_string_pretty())
                    .map_err(|e| Error::Io(format!("cannot write {path}: {e}")))?;
                println!("wrote {path}");
            }
            return Ok(());
        }

        let mut policy = policy_by_name(policy_name)?;

        if args.get("mode") == Some("real") {
            // Always-on real serving: the serve core's admission/
            // backpressure loop over the RealBackend (PJRT execution,
            // wall-clock pacing, bounded live state).
            let dir = args
                .get("artifacts")
                .map(PathBuf::from)
                .unwrap_or_else(default_artifact_dir);
            let runtime = Arc::new(Runtime::new(&dir)?);
            let calibrated = CalibratedCost::load(&dir.join("calibration.json")).ok();
            let cost: &dyn CostModel = match &calibrated {
                Some(c) => {
                    println!("cost model: calibrated ({}/calibration.json)", dir.display());
                    c
                }
                None => &PaperCost,
            };
            let wall = std::time::Instant::now();
            let report = match args.get("outcomes-jsonl") {
                Some(path) => {
                    let file = std::fs::File::create(path)
                        .map_err(|e| Error::Io(format!("cannot create {path}: {e}")))?;
                    let mut sink = JsonlSink::new(std::io::BufWriter::new(file));
                    let r = serve_real_stream(
                        requests,
                        &runtime,
                        &platform,
                        cost,
                        policy.as_mut(),
                        &scfg,
                        pacing,
                        prewarm,
                        seed,
                        &mut sink,
                    )?;
                    println!("wrote per-request outcomes to {path}");
                    r
                }
                None => serve_real_stream(
                    requests,
                    &runtime,
                    &platform,
                    cost,
                    policy.as_mut(),
                    &scfg,
                    pacing,
                    prewarm,
                    seed,
                    &mut NullSink,
                )?,
            };
            let wall_seconds = wall.elapsed().as_secs_f64();
            print!("{}", format_stream_summary(&report));
            if let Some(path) = args.get("json") {
                let json = serve_real_stream_json(&report, wall_seconds);
                std::fs::write(path, json.to_string_pretty())
                    .map_err(|e| Error::Io(format!("cannot write {path}: {e}")))?;
                println!("wrote {path}");
            }
            return Ok(());
        }

        let wall = std::time::Instant::now();
        let report = match args.get("outcomes-jsonl") {
            Some(path) => {
                let file = std::fs::File::create(path)
                    .map_err(|e| Error::Io(format!("cannot create {path}: {e}")))?;
                let mut sink = JsonlSink::new(std::io::BufWriter::new(file));
                let r = serve_stream(
                    requests,
                    &platform,
                    &PaperCost,
                    policy.as_mut(),
                    &scfg,
                    &mut sink,
                )?;
                println!("wrote per-request outcomes to {path}");
                r
            }
            None => serve_stream(
                requests,
                &platform,
                &PaperCost,
                policy.as_mut(),
                &scfg,
                &mut NullSink,
            )?,
        };
        let wall_seconds = wall.elapsed().as_secs_f64();
        print!("{}", format_stream_summary(&report));
        if let Some(path) = args.get("json") {
            let json = serve_soak_json(&report, wall_seconds, peak_rss_mb());
            std::fs::write(path, json.to_string_pretty())
                .map_err(|e| Error::Io(format!("cannot write {path}: {e}")))?;
            println!("wrote {path}");
        }
        return Ok(());
    }

    if args.get("mode") == Some("real") {
        if args.get("autoscale-target").is_some() {
            return Err(Error::Io(
                "--autoscale-target searches simulated platforms and is sim-only \
                 (drop --mode real)"
                    .into(),
            ));
        }
        let dir = args
            .get("artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(default_artifact_dir);
        let runtime = Arc::new(Runtime::new(&dir)?);
        let mut policy = policy_by_name(policy_name)?;
        // Real-path deadlines are wall-clock, so admission/EDF estimates
        // should be too: prefer the measured table from `pyschedcl
        // calibrate` when it exists; the paper's modeled times otherwise
        // (fine for ordering, coarse for admission — see README).
        let calibrated = CalibratedCost::load(&dir.join("calibration.json")).ok();
        let cost: &dyn CostModel = match &calibrated {
            Some(c) => {
                println!("cost model: calibrated ({}/calibration.json)", dir.display());
                c
            }
            None => &PaperCost,
        };
        let report = serve_real(
            &requests,
            &runtime,
            &platform,
            cost,
            policy.as_mut(),
            &cfg,
            seed,
        )?;
        print!("{}", format_real_summary(&report));
        if let Some(path) = args.get("json") {
            let json = Json::obj(vec![
                ("schema", Json::str("pyschedcl-serve-bench-v1")),
                ("real", report.to_json()),
            ]);
            std::fs::write(path, json.to_string_pretty())
                .map_err(|e| Error::Io(format!("cannot write {path}: {e}")))?;
            println!("wrote {path}");
        }
        return Ok(());
    }

    // SLO-aware autoscaling experiment: binary-search the smallest GPU
    // count whose simulated deadline-miss rate meets the target (the miss
    // rate is monotone non-increasing in GPU count for a fixed request
    // set), then serve the final comparison at that scale. The per-scale
    // report cache lets the chosen scale's report be reused below instead
    // of simulating it a second time.
    let mut autoscaled = None;
    if let Some(target_text) = args.get("autoscale-target") {
        let target: f64 = target_text.parse().map_err(|_| {
            Error::Io(format!(
                "invalid --autoscale-target '{target_text}' (expected a miss-rate fraction)"
            ))
        })?;
        if !(0.0..=1.0).contains(&target) {
            return Err(Error::Io(format!(
                "--autoscale-target {target} out of range (expected within [0, 1])"
            )));
        }
        let max_gpus = args.usize_or("autoscale-max-gpus", 8).max(1);
        let cpus = args.usize_or("cpus", 1);
        let q_gpu = args.usize_or("queues-gpu", 3);
        let q_cpu = args.usize_or("queues-cpu", 1);
        println!("autoscale: smallest GPU count with deadline-miss rate <= {target}");
        let mut outcome = autoscale_search(
            max_gpus,
            target,
            |gpus| {
                let candidate = Platform::scaled(gpus, cpus, q_gpu, q_cpu);
                let mut pol = policy_by_name(policy_name)?;
                let r = serve_sim(&requests, &candidate, &PaperCost, pol.as_mut(), &cfg)?;
                println!(
                    "  gpus={gpus}: miss rate {:.3} ({} of {} deadlines missed, p99 {:.1} ms)",
                    r.deadline_miss_rate,
                    r.deadline_misses,
                    r.deadline_total,
                    r.p99_latency * 1e3
                );
                Ok(r)
            },
            |r| r.deadline_miss_rate,
        )?;
        if outcome.reached {
            println!(
                "autoscale: chose {} GPU(s) after {} evaluation(s)",
                outcome.chosen,
                outcome.evaluations.len()
            );
        } else {
            println!(
                "autoscale: target {target} unreachable within {max_gpus} GPU(s); \
                 serving at the cap"
            );
        }
        platform = Platform::scaled(outcome.chosen, cpus, q_gpu, q_cpu);
        autoscaled = outcome.reports.remove(&outcome.chosen);
    }

    let concurrent = match autoscaled {
        Some(r) => r,
        None => {
            let mut policy = policy_by_name(policy_name)?;
            serve_sim(&requests, &platform, &PaperCost, policy.as_mut(), &cfg)?
        }
    };
    let mut policy = policy_by_name(policy_name)?;
    let sequential = serve_sequential(&requests, &platform, &PaperCost, policy.as_mut(), &cfg)?;
    print!("{}", format_serve_comparison(&concurrent, &sequential));

    if let Some(path) = args.get("json") {
        let json = serve_bench_json(&concurrent, &sequential);
        std::fs::write(path, json.to_string_pretty())
            .map_err(|e| Error::Io(format!("cannot write {path}: {e}")))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `pyschedcl bench-check`: compare a freshly produced `BENCH_*.json`
/// smoke artifact against a committed baseline and fail (typed
/// [`Error::Bench`], exit 1) when any gated metric moved beyond tolerance.
/// `--update` rewrites the baseline's bounds to the observed values
/// instead — the intentional re-baselining path.
fn cmd_bench_check(args: &Args) -> Result<()> {
    let baseline_path = args
        .get("baseline")
        .ok_or_else(|| Error::Io("bench-check requires --baseline FILE".into()))?;
    // Baseline problems (deleted, renamed, unparseable) are surfaced first
    // with a path-qualified message — CI fails the gate step early and
    // clearly instead of producing a confusing comparison failure. With
    // `--validate`, that is the *whole* job: CI loops it over every
    // committed baseline before spending minutes producing bench artifacts.
    let baseline = load_baseline(std::path::Path::new(baseline_path))?;
    if on_off_flag(args, "validate")? {
        println!(
            "baseline {baseline_path}: ok ({} check(s))",
            baseline.checks.len()
        );
        return Ok(());
    }
    let current_path = args
        .get("current")
        .ok_or_else(|| Error::Io("bench-check requires --current FILE".into()))?;
    let current_text = std::fs::read_to_string(current_path)
        .map_err(|e| Error::Io(format!("cannot read {current_path}: {e}")))?;
    let current = Json::parse(&current_text)?;

    // A bare `--update` parses as the value "true".
    let update = match args.get("update") {
        None | Some("false") | Some("off") => false,
        Some("true") | Some("on") => true,
        Some(other) => {
            return Err(Error::Io(format!(
                "unknown update '{other}' (expected on|off)"
            )))
        }
    };
    if update {
        let updated = update_baseline(&baseline, &current)?;
        std::fs::write(baseline_path, updated.to_string_pretty())
            .map_err(|e| Error::Io(format!("cannot write {baseline_path}: {e}")))?;
        println!("re-baselined {baseline_path} from {current_path}");
        return Ok(());
    }

    let tolerance = match args.get("tolerance") {
        Some(t) => Some(t.parse::<f64>().map_err(|_| {
            Error::Io(format!("invalid --tolerance '{t}' (expected a number)"))
        })?),
        None => None,
    };
    let results = check_bench(&baseline, &current, tolerance);
    print!("{}", format_gate(&results));
    // Inside a GitHub Actions step, also append the markdown flavor to the
    // job summary — on success as well as failure, so every green run still
    // shows the remaining headroom per gate. Best-effort: a summary-file IO
    // problem must not flip the gate's verdict.
    if let Ok(summary_path) = std::env::var("GITHUB_STEP_SUMMARY") {
        if !summary_path.is_empty() {
            use std::io::Write as _;
            let md = format_gate_markdown(current_path, &results);
            let appended = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&summary_path)
                .and_then(|mut f| f.write_all(md.as_bytes()));
            if let Err(e) = appended {
                eprintln!("warning: cannot append to GITHUB_STEP_SUMMARY ({summary_path}): {e}");
            }
        }
    }
    let failed = results.iter().filter(|r| !r.ok).count();
    if failed > 0 {
        return Err(Error::Bench(format!(
            "{failed} of {} gated metric(s) in {current_path} moved beyond \
             {baseline_path}'s tolerance",
            results.len()
        )));
    }
    println!(
        "all {} gated metric(s) within tolerance of {baseline_path}",
        results.len()
    );
    Ok(())
}

/// Strict on/off flag in the house style (`--shrink`, bare = "true").
fn on_off_flag(args: &Args, key: &str) -> Result<bool> {
    match args.get(key) {
        None | Some("false") | Some("off") => Ok(false),
        Some("true") | Some("on") => Ok(true),
        Some(other) => Err(Error::Io(format!(
            "unknown {key} '{other}' (expected on|off)"
        ))),
    }
}

/// `pyschedcl fuzz`: deterministic concurrency fuzzer for the scheduler
/// core ([`pyschedcl::sched::fuzz`]). Three modes:
///
/// * `--seeds N [--start S]` — sweep N seeds, print the aggregate
///   coverage table, and fail unless every ambiguity class provably
///   executed ≥ 2 distinct same-instant orderings;
/// * `--seed X [--shrink]` — replay one seed with its full deterministic
///   log, optionally shrinking a failure to a minimal reproducer;
/// * `--corpus DIR` — replay every committed `*.json` seed (the per-PR
///   CI regression gate), checking invariants and replay determinism.
fn cmd_fuzz(args: &Args) -> Result<()> {
    // Panics inside the fuzzed engines are caught and reported as
    // failures; silence the default hook so its stderr spew cannot make
    // two runs of the same seed differ.
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let out = cmd_fuzz_inner(args);
    std::panic::set_hook(hook);
    out
}

fn cmd_fuzz_inner(args: &Args) -> Result<()> {
    use pyschedcl::sched::fuzz::{load_corpus_seeds, run_many, run_seed, shrink_seed, FuzzConfig};
    let cfg = FuzzConfig {
        orderings: args.usize_or("orderings", 4).max(1),
        budget: args.get("budget").and_then(|v| v.parse().ok()),
        oracle_steps: args.usize_or("oracle-steps", 120),
    };
    let verbose = on_off_flag(args, "verbose")?;
    let shrink = on_off_flag(args, "shrink")?;

    // Corpus replay: the committed regression seeds (loading lives in the
    // library so the error contract is unit-tested there).
    if let Some(dir) = args.get("corpus") {
        let seeds = load_corpus_seeds(dir)?;
        let mut failed = 0usize;
        for cs in &seeds {
            let ccfg = FuzzConfig {
                orderings: cs.orderings,
                ..cfg
            };
            let rep = run_seed(cs.seed, &ccfg);
            let replay_identical = run_seed(cs.seed, &ccfg).log == rep.log;
            let ok = rep.ok() && replay_identical;
            println!(
                "corpus {}: seed {} [{}] {}",
                cs.path.file_name().and_then(|n| n.to_str()).unwrap_or("?"),
                cs.seed,
                cs.note,
                if ok { "ok" } else { "FAIL" }
            );
            if verbose {
                print!("{}", rep.log);
            }
            if !ok {
                for f in &rep.failures {
                    println!("  {f}");
                }
                if !replay_identical {
                    println!("  replay log diverged (non-deterministic)");
                }
                failed += 1;
            }
        }
        if failed > 0 {
            return Err(Error::Sched(format!("{failed} corpus seed(s) failed")));
        }
        println!("corpus: all {} seed(s) green", seeds.len());
        return Ok(());
    }

    // Single-seed replay (and optional shrink).
    if let Some(seed_text) = args.get("seed") {
        let seed: u64 = seed_text
            .parse()
            .map_err(|_| Error::Io(format!("invalid --seed '{seed_text}' (expected a u64)")))?;
        let rep = run_seed(seed, &cfg);
        print!("{}", rep.log);
        if shrink {
            match shrink_seed(seed, &cfg) {
                Some(s) => print!("{}", s.log),
                None => println!("shrink: seed {seed} passes every ordering; nothing to shrink"),
            }
        }
        if !rep.ok() {
            return Err(Error::Sched(format!(
                "fuzz seed {seed} failed: {}",
                rep.failures[0]
            )));
        }
        return Ok(());
    }

    // Seed sweep with the coverage assertion.
    let n = args.u64_or("seeds", 50).max(1);
    let start = args.u64_or("start", 0);
    let summary = run_many(start, n, &cfg, |rep| {
        if verbose {
            print!("{}", rep.log);
        } else if !rep.ok() {
            println!("seed {}: FAIL ({})", rep.seed, rep.failures[0]);
        }
    });
    print!("{}", summary.render());

    if let Some(seed) = summary.failures.first().map(|(s, _)| *s) {
        let shrunk = shrink_seed(seed, &cfg);
        if let Some(s) = &shrunk {
            print!("{}", s.log);
        }
        if let Some(dir) = args.get("report-dir") {
            std::fs::create_dir_all(dir)
                .map_err(|e| Error::Io(format!("cannot create {dir}: {e}")))?;
            let failing = format!("{dir}/fuzz_failing_seed.txt");
            std::fs::write(&failing, &run_seed(seed, &cfg).log)
                .map_err(|e| Error::Io(format!("cannot write {failing}: {e}")))?;
            println!("wrote {failing}");
            if let Some(s) = &shrunk {
                let repro = format!("{dir}/fuzz_reproducer.txt");
                std::fs::write(&repro, &s.log)
                    .map_err(|e| Error::Io(format!("cannot write {repro}: {e}")))?;
                println!("wrote {repro}");
            }
        }
        return Err(Error::Sched(format!(
            "{} of {n} fuzz seed(s) failed",
            summary.failures.len()
        )));
    }
    let unproven = summary.unproven_classes();
    if !unproven.is_empty() {
        return Err(Error::Sched(format!(
            "ambiguity classes without >=2 distinct executed orderings: {unproven:?}"
        )));
    }
    println!(
        "fuzz: {n} seed(s) green; every ambiguity class executed >=2 distinct orderings"
    );
    Ok(())
}

fn main_inner() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprintln!(
            "usage: pyschedcl <inspect|simulate|run|serve|bench-check|fuzz|motivation|expt1|\
             expt2|expt3|gantt|calibrate|autotune> ..."
        );
        std::process::exit(2);
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "inspect" => cmd_inspect(&args),
        "simulate" => cmd_simulate(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "bench-check" => cmd_bench_check(&args),
        "fuzz" => cmd_fuzz(&args),
        "motivation" => cmd_motivation(&args),
        "expt1" => {
            let rows = expts::expt1(
                args.usize_or("hmax", 16),
                args.u64_or("beta", 256),
                args.usize_or("hcpu-max", 3),
            )?;
            print!("{}", expts::format_expt1(&rows));
            Ok(())
        }
        "expt2" => {
            let rows = expts::expt2(args.usize_or("heads", 16), &parse_betas(&args))?;
            print!("{}", expts::format_baseline(&rows, "eager"));
            Ok(())
        }
        "expt3" => {
            let rows = expts::expt3(args.usize_or("heads", 16), &parse_betas(&args))?;
            print!("{}", expts::format_baseline(&rows, "heft"));
            Ok(())
        }
        "gantt" => {
            let (_, s) = expts::gantt(
                args.get("policy").unwrap_or("clustering"),
                args.usize_or("heads", 16),
                args.u64_or("beta", 512),
            )?;
            print!("{s}");
            Ok(())
        }
        "calibrate" => cmd_calibrate(&args),
        "autotune" => {
            use pyschedcl::sched::autotune::{exhaustive, hill_climb, TuneSpace};
            let heads = args.usize_or("heads", 16);
            let beta = args.u64_or("beta", 256);
            let space = TuneSpace::default();
            let r = match args.get("strategy").unwrap_or("hill") {
                "exhaustive" => exhaustive(heads, beta, space, &PaperCost)?,
                _ => hill_climb(heads, beta, space, expts::DEFAULT_MC, &PaperCost)?,
            };
            println!(
                "best mc = {}  makespan = {:.1} ms  ({} evaluations)",
                r.best,
                r.makespan * 1e3,
                r.evals
            );
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'");
            std::process::exit(2);
        }
    }
}

fn main() {
    if let Err(e) = main_inner() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
