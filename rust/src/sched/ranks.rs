//! Bottom-level rank computation at task-component granularity.

use crate::cost::CostModel;
use crate::graph::{bottom_level_ranks, Dag, Partition};
use crate::platform::Platform;

/// Per-kernel bottom-level ranks using HEFT's cross-device mean weights.
pub fn kernel_ranks(dag: &Dag, platform: &Platform, cost: &dyn CostModel) -> Vec<f64> {
    let devs: Vec<&crate::platform::Device> = platform.devices.iter().collect();
    let weights: Vec<f64> = dag
        .kernels
        .iter()
        .map(|k| cost.mean_time(k, &devs))
        .collect();
    bottom_level_ranks(dag, &weights)
}

/// Component rank = max bottom-level rank over the component's kernels
/// (the paper annotates each component with the max rank of `FRONT(T)`;
/// FRONT kernels dominate their component so the max over all members is
/// identical, and also covers components with empty FRONT).
pub fn component_ranks(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
) -> Vec<f64> {
    let kr = kernel_ranks(dag, platform, cost);
    partition
        .components
        .iter()
        .map(|c| c.kernels.iter().map(|&k| kr[k]).fold(0.0, f64::max))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::AnalyticCost;
    use crate::platform::{DeviceType, Platform};
    use crate::transformer::{cluster_by_head, transformer_dag};

    #[test]
    fn head_components_have_equal_ranks() {
        let (dag, ios) = transformer_dag(3, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let p = Platform::paper_testbed(3, 1);
        let ranks = component_ranks(&dag, &part, &p, &AnalyticCost);
        assert_eq!(ranks.len(), 3);
        assert!((ranks[0] - ranks[1]).abs() < 1e-12);
        assert!((ranks[1] - ranks[2]).abs() < 1e-12);
        assert!(ranks[0] > 0.0);
    }

    #[test]
    fn rank_dominated_by_critical_path() {
        let (dag, ios) = transformer_dag(1, 128, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let p = Platform::paper_testbed(1, 1);
        let kr = kernel_ranks(&dag, &p, &AnalyticCost);
        // The Q-projection GEMM heads the longest chain: its rank must
        // exceed the output GEMM's rank.
        let io = &ios[0];
        assert!(kr[io.kernels[0]] > kr[io.kernels[7]]);
        let cr = component_ranks(&dag, &part, &p, &AnalyticCost);
        assert!((cr[0] - kr[io.kernels[0]].max(kr[io.kernels[1]])).abs() < 1e-9);
    }
}
