//! Seeded workload generation for the concurrency fuzzer.
//!
//! Every workload is a pure function of its seed. Two seeds are *crafted*
//! shapes that guarantee choice-point coverage for specific ambiguity
//! classes on every fuzz run (so the coverage assertion in the report can
//! never go flaky), the rest are randomized over the repo's application
//! generators with **gridded** time values — releases and deadlines drawn
//! from a coarse lattice, plus forced bitwise-equal deadline copies — so
//! same-instant collisions and exact tie-breaks are common instead of
//! measure-zero:
//!
//! * seed 0 (`twin-ties`): identical independent GPU components with one
//!   shared bitwise deadline on a two-GPU platform — guaranteed
//!   dispatch-tie, simultaneous-completion, and callback-batch sites.
//! * seed 1 (`preempt-storm`): two ∞-deadline tenants filling both GPUs,
//!   then a tight-deadline arrival that must displace one — guaranteed
//!   preempt-race (two equal victims) and re-entry sites.

use crate::cost::{CostModel, PaperCost};
use crate::fault::{FaultEvent, FaultKind, FaultPlan};
use crate::graph::{Dag, Partition};
use crate::platform::{DeviceType, Platform};
use crate::sched::{Clustering, Edf, LeastLoaded, Policy};
use crate::sim::{CompMeta, SimConfig};
use crate::transformer::{cluster_by_head, transformer_dag};

/// The repo-standard xorshift64* stream (same constants as
/// `tests/prop_invariants.rs`).
pub(crate) struct Rng(u64);

impl Rng {
    pub(crate) fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1))
    }

    pub(crate) fn next(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    pub(crate) fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }

    pub(crate) fn chance(&mut self, one_in: usize) -> bool {
        self.below(one_in) == 0
    }
}

/// Which policy a workload runs under. Edf-biased: it is the only shipped
/// policy with a preemption rule, so it exercises every ambiguity class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    Edf,
    LeastLoaded,
    Clustering,
}

impl PolicyKind {
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Edf => "edf",
            PolicyKind::LeastLoaded => "least-loaded",
            PolicyKind::Clustering => "clustering",
        }
    }

    pub fn build(self) -> Box<dyn Policy> {
        match self {
            PolicyKind::Edf => Box::new(Edf),
            PolicyKind::LeastLoaded => Box::new(LeastLoaded),
            PolicyKind::Clustering => Box::new(Clustering),
        }
    }
}

/// One engine-path fuzz workload: a served application plus everything
/// `simulate_served` needs.
pub struct Workload {
    pub label: String,
    pub dag: Dag,
    pub partition: Partition,
    pub platform: Platform,
    pub cfg: SimConfig,
    pub meta: Vec<CompMeta>,
    pub policy: PolicyKind,
}

/// One admitted unit of a stream-path fuzz plan: the whole template enters
/// as a single request at `release`.
pub struct UnitPlan {
    pub release: f64,
    /// Relative deadline budget (absolute = release + budget).
    pub deadline: Option<f64>,
    pub priority: u32,
}

/// A stream-path fuzz plan: several units of one template admitted up
/// front, then pumped to idle.
pub struct StreamPlan {
    pub label: String,
    pub dag: Dag,
    pub partition: Partition,
    pub platform: Platform,
    pub cfg: SimConfig,
    pub policy: PolicyKind,
    pub units: Vec<UnitPlan>,
}

fn template(heads: usize, beta: u64, h_cpu: usize) -> (Dag, Partition) {
    let (dag, ios) = transformer_dag(heads, beta, DeviceType::Gpu);
    let part = cluster_by_head(&dag, &ios, h_cpu);
    (dag, part)
}

fn cfg_with_tenants(max_tenants: usize) -> SimConfig {
    SimConfig {
        max_tenants,
        ..SimConfig::default()
    }
}

/// The coarse time lattice: multiples of 1.5 ms, far above the platform's
/// sub-millisecond overheads, so distinct grid points never collide by
/// accident while equal ones collide exactly.
const GRID: f64 = 1.5e-3;

/// Solo GPU seconds of one head of `dag` (total serial work over `heads`):
/// the calibration unit for the crafted preemption shape, so its tight
/// arrival is guaranteed to land while the residents are still mid-run
/// whatever the cost model says.
fn head_solo_seconds(dag: &Dag, platform: &Platform, heads: usize) -> f64 {
    let gpu = &platform.devices[0];
    let total: f64 = dag.kernels.iter().map(|k| PaperCost.exec_time(k, gpu)).sum();
    total / heads as f64
}

/// The engine-path workload for `seed` (pure function of the seed).
pub fn engine_workload(seed: u64) -> Workload {
    match seed {
        0 => {
            let (dag, partition) = template(4, 64, 0);
            let ncomp = partition.components.len();
            let meta = vec![
                CompMeta {
                    release: 0.0,
                    deadline: 0.05,
                    priority: 0,
                };
                ncomp
            ];
            Workload {
                label: "twin-ties: 4 identical comps, shared bitwise deadline, 2 GPUs".into(),
                dag,
                partition,
                platform: Platform::scaled(2, 1, 2, 1),
                cfg: cfg_with_tenants(2),
                meta,
                policy: PolicyKind::Edf,
            }
        }
        1 => {
            let (dag, partition) = template(3, 128, 0);
            let ncomp = partition.components.len();
            let platform = Platform::scaled(2, 1, 2, 1);
            let head_t = head_solo_seconds(&dag, &platform, 3);
            let mut meta = vec![CompMeta::default(); ncomp];
            // Last component: a late, tight-deadline arrival (5% into the
            // residents' runs) that must displace one of the two equally
            // unhurried residents.
            meta[ncomp - 1] = CompMeta {
                release: 0.05 * head_t,
                deadline: 0.05 * head_t + 1.5 * head_t,
                priority: 1,
            };
            Workload {
                label: "preempt-storm: 2 resident ∞-deadline tenants + tight arrival".into(),
                dag,
                partition,
                platform,
                cfg: cfg_with_tenants(1),
                meta,
                policy: PolicyKind::Edf,
            }
        }
        _ => {
            let mut rng = Rng::new(seed);
            let heads = 2 + rng.below(3);
            let beta = [32u64, 64, 128][rng.below(3)];
            let h_cpu = rng.below(2).min(heads - 1);
            let (dag, partition) = template(heads, beta, h_cpu);
            let ncomp = partition.components.len();
            let platform = Platform::scaled(1 + rng.below(2), 1, 1 + rng.below(2), 1);
            let cfg = cfg_with_tenants(1 + rng.below(2));
            let policy = match rng.below(4) {
                0 => PolicyKind::LeastLoaded,
                1 => PolicyKind::Clustering,
                _ => PolicyKind::Edf,
            };
            let mut meta = Vec::with_capacity(ncomp);
            for c in 0..ncomp {
                let release = if rng.chance(2) {
                    0.0
                } else {
                    rng.below(4) as f64 * GRID
                };
                let deadline = if rng.chance(3) {
                    f64::INFINITY
                } else {
                    release + (1 + rng.below(4)) as f64 * 4.0 * GRID
                };
                let mut m = CompMeta {
                    release,
                    deadline,
                    priority: rng.below(2) as u32,
                };
                // Forced bitwise deadline tie with the previous component.
                if c > 0 && rng.chance(4) {
                    let prev: &CompMeta = &meta[c - 1];
                    m.deadline = prev.deadline;
                    m.priority = prev.priority;
                }
                meta.push(m);
            }
            Workload {
                label: format!(
                    "random: {heads}x beta={beta} h_cpu={h_cpu} tenants={} policy={}",
                    cfg.max_tenants,
                    policy.name()
                ),
                dag,
                partition,
                platform,
                cfg,
                meta,
                policy,
            }
        }
    }
}

/// The stream-path plan for `seed` (pure function of the seed).
pub fn stream_plan(seed: u64) -> StreamPlan {
    match seed {
        0 => {
            let (dag, partition) = template(4, 64, 0);
            StreamPlan {
                label: "twin-ties stream: two units, same release instant".into(),
                dag,
                partition,
                platform: Platform::scaled(2, 1, 2, 1),
                cfg: cfg_with_tenants(2),
                policy: PolicyKind::Edf,
                units: vec![
                    UnitPlan {
                        release: 0.0,
                        deadline: Some(0.05),
                        priority: 0,
                    },
                    UnitPlan {
                        release: 0.0,
                        deadline: Some(0.05),
                        priority: 0,
                    },
                ],
            }
        }
        1 => {
            let (dag, partition) = template(3, 128, 0);
            let platform = Platform::scaled(2, 1, 2, 1);
            let head_t = head_solo_seconds(&dag, &platform, 3);
            StreamPlan {
                label: "preempt-storm stream: ∞-deadline unit + tight arrival".into(),
                dag,
                partition,
                platform,
                cfg: cfg_with_tenants(1),
                policy: PolicyKind::Edf,
                units: vec![
                    UnitPlan {
                        release: 0.0,
                        deadline: None,
                        priority: 0,
                    },
                    UnitPlan {
                        release: 0.05 * head_t,
                        deadline: Some(1.5 * head_t),
                        priority: 1,
                    },
                ],
            }
        }
        _ => {
            let mut rng = Rng::new(seed ^ 0xB5AD_4ECE_DA1C_E2A9);
            let heads = 2 + rng.below(3);
            let beta = [32u64, 64, 128][rng.below(3)];
            let h_cpu = rng.below(2).min(heads - 1);
            let (dag, partition) = template(heads, beta, h_cpu);
            let platform = Platform::scaled(1 + rng.below(2), 1, 1 + rng.below(2), 1);
            let cfg = cfg_with_tenants(1 + rng.below(2));
            let policy = if rng.below(4) == 0 {
                PolicyKind::LeastLoaded
            } else {
                PolicyKind::Edf
            };
            let n_units = 2 + rng.below(2);
            let mut units = Vec::with_capacity(n_units);
            for _ in 0..n_units {
                let release = if rng.chance(2) {
                    0.0
                } else {
                    rng.below(4) as f64 * GRID
                };
                units.push(UnitPlan {
                    release,
                    deadline: if rng.chance(3) {
                        None
                    } else {
                        Some((1 + rng.below(4)) as f64 * 4.0 * GRID)
                    },
                    priority: rng.below(2) as u32,
                });
            }
            StreamPlan {
                label: format!(
                    "random stream: {n_units} units of {heads}x beta={beta} tenants={} policy={}",
                    cfg.max_tenants,
                    policy.name()
                ),
                dag,
                partition,
                platform,
                cfg,
                policy,
                units,
            }
        }
    }
}

/// The fault-injection plan for stream-path seed `seed` on an
/// `ndev`-device platform (pure function of both). Crafted seeds 0 and 1
/// stay fault-free — their coverage guarantees for the other ambiguity
/// classes must never depend on chaos — and so does half of the random
/// space, keeping the zero-fault byte-identical paths under fuzz too.
/// Fault seeds alternate between a single mid-run crash (never the whole
/// platform: one device always survives) and a wedge+slowdown pair, both
/// on the same coarse grid the workload times use so fault instants
/// collide exactly with completions — the fault-race ambiguity.
pub fn fault_plan(seed: u64, ndev: usize) -> Option<FaultPlan> {
    if seed < 2 || ndev < 2 {
        return None;
    }
    let mut rng = Rng::new(seed ^ 0xD6E8_FEB8_6659_FD93);
    match seed % 4 {
        2 => {
            let plan = FaultPlan {
                events: vec![FaultEvent {
                    device: rng.below(ndev),
                    at: (1 + rng.below(4)) as f64 * GRID,
                    kind: FaultKind::Crash,
                }],
                retry_budget: 2,
                backoff_base: 1e-4,
                ..FaultPlan::default()
            };
            Some(plan.normalized().expect("crafted crash plan is valid"))
        }
        3 => {
            let wedge_dev = rng.below(ndev);
            let slow_dev = rng.below(ndev);
            let plan = FaultPlan {
                events: vec![
                    FaultEvent {
                        device: wedge_dev,
                        at: (1 + rng.below(3)) as f64 * GRID,
                        kind: FaultKind::Wedge { dur: 2.0 * GRID },
                    },
                    FaultEvent {
                        device: slow_dev,
                        at: (1 + rng.below(4)) as f64 * GRID,
                        kind: FaultKind::Slowdown { factor: 0.5 },
                    },
                ],
                retry_budget: 3,
                backoff_base: 1e-4,
                ..FaultPlan::default()
            };
            Some(plan.normalized().expect("crafted wedge plan is valid"))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workloads_are_pure_functions_of_the_seed() {
        for seed in [0u64, 1, 2, 17, 123] {
            let a = engine_workload(seed);
            let b = engine_workload(seed);
            assert_eq!(a.label, b.label);
            assert_eq!(a.meta.len(), b.meta.len());
            for (x, y) in a.meta.iter().zip(&b.meta) {
                assert_eq!(x.release.to_bits(), y.release.to_bits());
                assert_eq!(x.deadline.to_bits(), y.deadline.to_bits());
                assert_eq!(x.priority, y.priority);
            }
            let p = stream_plan(seed);
            let q = stream_plan(seed);
            assert_eq!(p.label, q.label);
            assert_eq!(p.units.len(), q.units.len());
        }
    }

    #[test]
    fn crafted_shapes_have_the_advertised_structure() {
        let w = engine_workload(0);
        assert!(w.meta.len() >= 4);
        let d0 = w.meta[0].deadline.to_bits();
        assert!(w.meta.iter().all(|m| m.deadline.to_bits() == d0));
        assert!(w.meta.iter().all(|m| m.release == 0.0));

        let w = engine_workload(1);
        let n = w.meta.len();
        assert!(w.meta[..n - 1].iter().all(|m| m.deadline.is_infinite()));
        assert!(w.meta[n - 1].deadline.is_finite());
        assert!(w.meta[n - 1].release > 0.0);
        assert_eq!(w.cfg.max_tenants, 1);
    }
}
