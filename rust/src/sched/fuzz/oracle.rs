//! The fuzzer's core oracle: drive a slot-mode [`SchedState`] through a
//! seeded random event sequence while checking, after **every** event,
//! that
//!
//! 1. the state's internal structural invariants hold
//!    ([`SchedState::check_invariants`]): frontier count, tenancy /
//!    availability bookkeeping, and every live heap entry's key matching
//!    the component facts it indexes;
//! 2. a `SchedState` **rebuilt from scratch** — fresh state, same slot
//!    bindings, the current frontier re-entered in its original ready
//!    order, the current residents re-dispatched in their original
//!    dispatch order — answers every scheduling query identically to the
//!    incrementally maintained one (same frontier order, same heads, same
//!    tie lists, same tenancy, bit-equal laxities); and
//! 3. [`SchedState::compact_heaps`] is behavior-neutral (identical
//!    queries before and after).
//!
//! The rebuild oracle replays from an **independent shadow model** (its
//! own ready/dispatch chronology), not from the state's internals, so a
//! lost, duplicated, or mis-keyed heap entry in the incremental path
//! cannot hide itself.

use super::gen::Rng;
use crate::cost::PaperCost;
use crate::graph::{Dag, Partition};
use crate::platform::{DeviceType, Platform};
use crate::sched::SchedState;

#[derive(Clone, Copy, PartialEq)]
enum SlotState {
    /// Bound but not in the frontier and not resident.
    Idle,
    Ready,
    Resident(usize),
}

#[derive(Clone)]
struct SlotFacts {
    rank: f64,
    pref: DeviceType,
    deadline: f64,
    priority: u32,
    dev_times: Vec<f64>,
}

/// Counters from one oracle run, for the fuzz report.
#[derive(Debug, Clone, Copy, Default)]
pub struct OracleStats {
    pub steps: usize,
    pub rebuilds: usize,
    pub compactions: usize,
}

/// Snapshot of every order-sensitive query, for before/after comparisons.
fn query_snapshot(st: &mut SchedState) -> (Vec<usize>, Vec<usize>, Vec<usize>, Vec<usize>) {
    (
        st.frontier_ranked(),
        st.rank_head_ties(),
        st.urgency_head_ties(false),
        st.urgency_head_ties(true),
    )
}

/// Run `steps` random events against one persistent slot-mode state,
/// checking the three oracle properties throughout. Returns counters, or a
/// divergence description.
pub fn fuzz_state_events(seed: u64, steps: usize) -> Result<OracleStats, String> {
    let empty_dag = Dag::default();
    let empty_part = Partition {
        components: Vec::new(),
        assignment: Vec::new(),
    };
    let mut rng = Rng::new(seed ^ 0xD6E8_FEB8_6659_FD93);
    let platform = Platform::scaled(2, 1, 2, 1);
    let cost = PaperCost;
    let tenancy = 1 + rng.below(2);
    let ndev = platform.devices.len();

    let mut inc = SchedState::for_streaming(&empty_dag, &empty_part, &platform, &cost, tenancy)
        .map_err(|e| format!("state construction failed: {e}"))?;

    let nslots = 4 + rng.below(5);
    let mut facts: Vec<SlotFacts> = Vec::with_capacity(nslots);
    let mut slot_state = vec![SlotState::Idle; nslots];
    // Independent chronology shadows: frontier-entry order and dispatch
    // order of the *currently* live population.
    let mut ready_order: Vec<usize> = Vec::new();
    let mut resident_order: Vec<(usize, usize)> = Vec::new();

    let bind = |rng: &mut Rng| -> SlotFacts {
        SlotFacts {
            // Coarse grids force bitwise rank/deadline ties.
            rank: (1 + rng.below(3)) as f64,
            pref: if rng.below(3) == 0 {
                DeviceType::Cpu
            } else {
                DeviceType::Gpu
            },
            deadline: if rng.below(3) == 0 {
                f64::INFINITY
            } else {
                (1 + rng.below(4)) as f64 * 0.01
            },
            priority: rng.below(3) as u32,
            dev_times: (0..ndev).map(|d| (1 + (d + 1) % 3) as f64 * 1e-3).collect(),
        }
    };
    for slot in 0..nslots {
        let f = bind(&mut rng);
        inc.set_slot(slot, f.rank, f.pref, f.deadline, f.priority, &f.dev_times);
        facts.push(f);
    }

    let mut stats = OracleStats::default();
    for step in 0..steps {
        inc.now = step as f64 * 1e-3;
        // Pick an applicable random action.
        match rng.below(6) {
            // Ready an idle slot.
            0 | 1 => {
                let idle: Vec<usize> = (0..nslots)
                    .filter(|&s| slot_state[s] == SlotState::Idle)
                    .collect();
                if let Some(&s) = idle.get(rng.below(idle.len().max(1))) {
                    inc.on_ready(s);
                    slot_state[s] = SlotState::Ready;
                    ready_order.push(s);
                }
            }
            // Dispatch a frontier slot to an available device.
            2 | 3 => {
                let ready: Vec<usize> = (0..nslots)
                    .filter(|&s| slot_state[s] == SlotState::Ready)
                    .collect();
                let avail: Vec<usize> = (0..ndev).filter(|&d| inc.is_available(d)).collect();
                if !ready.is_empty() && !avail.is_empty() {
                    let s = ready[rng.below(ready.len())];
                    let d = avail[rng.below(avail.len())];
                    inc.on_dispatch(s, d);
                    slot_state[s] = SlotState::Resident(d);
                    ready_order.retain(|&x| x != s);
                    resident_order.push((s, d));
                }
            }
            // Complete a resident slot, sometimes rebinding it (slot reuse).
            4 => {
                if let Some(i) = pick_resident(&resident_order, &mut rng) {
                    let (s, d) = resident_order.remove(i);
                    inc.on_complete(d);
                    slot_state[s] = SlotState::Idle;
                    if rng.chance(2) {
                        let f = bind(&mut rng);
                        inc.set_slot(s, f.rank, f.pref, f.deadline, f.priority, &f.dev_times);
                        facts[s] = f;
                    }
                }
            }
            // Preempt a resident slot; usually re-enter it immediately.
            _ => {
                if let Some(i) = pick_resident(&resident_order, &mut rng) {
                    let (s, d) = resident_order.remove(i);
                    inc.on_preempt(d);
                    if rng.chance(4) {
                        slot_state[s] = SlotState::Idle;
                    } else {
                        inc.on_ready(s);
                        slot_state[s] = SlotState::Ready;
                        ready_order.push(s);
                    }
                }
            }
        }
        // Exercise the documented on_ready no-op path.
        if rng.chance(8) {
            if let Some(&s) = ready_order.first() {
                inc.on_ready(s);
            }
        }
        stats.steps += 1;

        // Oracle 1: structural invariants after every event.
        inc.check_invariants()
            .map_err(|e| format!("step {step}: invariants violated: {e}"))?;

        // Oracle 3: compaction neutrality, occasionally.
        if rng.chance(9) {
            let before = query_snapshot(&mut inc);
            inc.compact_heaps();
            let after = query_snapshot(&mut inc);
            if before != after {
                return Err(format!(
                    "step {step}: compact_heaps changed query results: {before:?} vs {after:?}"
                ));
            }
            inc.check_invariants()
                .map_err(|e| format!("step {step}: invariants violated after compaction: {e}"))?;
            stats.compactions += 1;
        }

        // Oracle 2: from-scratch rebuild equivalence, every few events.
        if step % 5 == 4 {
            rebuild_and_compare(
                &mut inc,
                &platform,
                &cost,
                tenancy,
                &facts,
                &ready_order,
                &resident_order,
            )
            .map_err(|e| format!("step {step}: rebuild divergence: {e}"))?;
            stats.rebuilds += 1;
        }
    }
    Ok(stats)
}

fn pick_resident(resident: &[(usize, usize)], rng: &mut Rng) -> Option<usize> {
    if resident.is_empty() {
        None
    } else {
        Some(rng.below(resident.len()))
    }
}

/// Build a fresh state from the shadow chronology and compare every
/// scheduling query against the incrementally maintained state.
fn rebuild_and_compare(
    inc: &mut SchedState,
    platform: &Platform,
    cost: &PaperCost,
    tenancy: usize,
    facts: &[SlotFacts],
    ready_order: &[usize],
    resident_order: &[(usize, usize)],
) -> Result<(), String> {
    let empty_dag = Dag::default();
    let empty_part = Partition {
        components: Vec::new(),
        assignment: Vec::new(),
    };
    let mut fresh = SchedState::for_streaming(&empty_dag, &empty_part, platform, cost, tenancy)
        .map_err(|e| format!("fresh state construction failed: {e}"))?;
    for (slot, f) in facts.iter().enumerate() {
        fresh.set_slot(slot, f.rank, f.pref, f.deadline, f.priority, &f.dev_times);
    }
    // Residents first (ready + dispatch in dispatch chronology), then the
    // live frontier in its entry chronology — the live entry seqs end up
    // in the same relative order as the incremental state's.
    for &(s, d) in resident_order {
        fresh.on_ready(s);
        fresh.on_dispatch(s, d);
    }
    for &s in ready_order {
        fresh.on_ready(s);
    }
    // Engine-owned inputs are copied, not reconstructed.
    fresh.now = inc.now;
    fresh.est_free.copy_from_slice(&inc.est_free);
    fresh.device_load.copy_from_slice(&inc.device_load);

    fresh
        .check_invariants()
        .map_err(|e| format!("rebuilt state invariants: {e}"))?;
    if fresh.frontier_len() != inc.frontier_len() {
        return Err(format!(
            "frontier_len {} vs rebuilt {}",
            inc.frontier_len(),
            fresh.frontier_len()
        ));
    }
    if fresh.tenants != inc.tenants {
        return Err(format!("tenants {:?} vs rebuilt {:?}", inc.tenants, fresh.tenants));
    }
    for d in 0..platform.devices.len() {
        if fresh.is_available(d) != inc.is_available(d) {
            return Err(format!("device {d} availability diverged"));
        }
    }
    let a = query_snapshot(inc);
    let b = query_snapshot(&mut fresh);
    if a != b {
        return Err(format!("query snapshot {a:?} vs rebuilt {b:?}"));
    }
    if inc.rank_head() != fresh.rank_head()
        || inc.urgency_head(false) != fresh.urgency_head(false)
        || inc.urgency_head(true) != fresh.urgency_head(true)
        || inc.rank_head_placeable() != fresh.rank_head_placeable()
    {
        return Err("head query diverged".into());
    }
    for &c in &a.0 {
        if inc.laxity(c).to_bits() != fresh.laxity(c).to_bits() {
            return Err(format!("laxity of component {c} diverged"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_runs_clean_over_many_seeds() {
        for seed in 0..40u64 {
            let stats = fuzz_state_events(seed, 120)
                .unwrap_or_else(|e| panic!("oracle seed {seed}: {e}"));
            assert_eq!(stats.steps, 120);
            assert!(stats.rebuilds >= 20, "seed {seed}: too few rebuilds");
        }
    }

    #[test]
    fn oracle_is_deterministic() {
        let a = fuzz_state_events(7, 200).unwrap();
        let b = fuzz_state_events(7, 200).unwrap();
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.rebuilds, b.rebuilds);
        assert_eq!(a.compactions, b.compactions);
    }
}
