//! Minimal-deviation reproducers for failing fuzz seeds.
//!
//! A failing ordering may have deviated from the canonical order at
//! hundreds of choice sites; almost all of those deviations are noise.
//! The [`OrderSeam`](super::OrderSeam) budget gives an exact prefix
//! semantics — a budget-`b` run is bit-identical to the unrestricted run
//! up through its `b`-th deviation and canonical afterwards — so the
//! shrinker can binary-search the smallest deviation prefix that still
//! reproduces the failure. The result is what gets pasted into a corpus
//! seed note: "seed S, ordering O, fails with N deviation(s)".

use super::{run_engine_path, run_stream_path, Decision, FuzzConfig, PathRun};
use std::fmt::Write as _;

/// Which execution path a failure came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailingRun {
    Engine,
    Stream,
}

impl FailingRun {
    pub fn name(self) -> &'static str {
        match self {
            FailingRun::Engine => "engine",
            FailingRun::Stream => "stream",
        }
    }
}

/// A shrunk reproducer: the failing (seed, ordering, path) plus the
/// smallest verified-failing deviation budget and its decision log.
pub struct ShrinkResult {
    pub seed: u64,
    pub ordering: usize,
    pub path: FailingRun,
    /// Deviations the unrestricted failing run made.
    pub full_deviations: u64,
    /// Smallest verified-failing deviation budget found. Replay with
    /// `OrderSeam::with_budget(seam_seed, Some(minimal_budget))`.
    pub minimal_budget: u64,
    /// Failure message of the minimal run.
    pub failure: String,
    /// Decision log of the minimal run.
    pub decisions: Vec<Decision>,
    /// Deterministic human-readable transcript of the shrink.
    pub log: String,
}

fn run_path(path: FailingRun, seed: u64, ordering: usize, budget: Option<u64>) -> PathRun {
    match path {
        FailingRun::Engine => run_engine_path(seed, ordering, budget),
        FailingRun::Stream => run_stream_path(seed, ordering, budget),
    }
}

/// Re-scan `seed` for a failure and shrink it. Returns `None` when every
/// ordering of every path passes (nothing to shrink).
///
/// The search keeps the classic invariant "`hi` is a verified-failing
/// budget": failures need not be monotone in the budget (a shorter
/// deviation prefix can dodge the bug), so the result is a *verified*
/// small reproducer, not necessarily the global minimum.
pub fn shrink_seed(seed: u64, cfg: &FuzzConfig) -> Option<ShrinkResult> {
    let orderings = cfg.orderings.max(1);
    let mut found: Option<(FailingRun, usize, PathRun)> = None;
    'scan: for o in 0..orderings {
        let budget = super::ordering_budget(cfg, o);
        for path in [FailingRun::Engine, FailingRun::Stream] {
            let run = run_path(path, seed, o, budget);
            if run.failure.is_some() {
                found = Some((path, o, run));
                break 'scan;
            }
        }
    }
    let (path, ordering, full) = found?;
    let full_deviations = full.deviations;

    let mut log = String::new();
    let _ = writeln!(
        log,
        "shrink seed {seed}: {} ordering {ordering} fails with {full_deviations} deviation(s)",
        path.name()
    );
    let _ = writeln!(
        log,
        "  full failure: {}",
        full.failure.as_deref().unwrap_or("<none>")
    );

    // Outcome of the current best (smallest verified-failing) budget.
    let mut best = (
        full.failure.clone().unwrap_or_default(),
        full.decisions.clone(),
    );
    let mut lo = 0u64;
    let mut hi = full_deviations;
    // An exact-budget replay is bit-identical to the unrestricted run by
    // construction; verify rather than assume.
    match run_path(path, seed, ordering, Some(hi)).failure {
        Some(f) => {
            best.0 = f;
        }
        None => {
            let _ = writeln!(
                log,
                "  WARNING: exact-budget replay passed; reporting the unrestricted run"
            );
            lo = hi;
        }
    }
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        let run = run_path(path, seed, ordering, Some(mid));
        match run.failure {
            Some(f) => {
                let _ = writeln!(log, "  budget {mid}: FAIL ({f})");
                best = (f, run.decisions);
                hi = mid;
            }
            None => {
                let _ = writeln!(log, "  budget {mid}: ok");
                lo = mid + 1;
            }
        }
    }
    let _ = writeln!(log, "  minimal verified budget: {hi}");
    for d in &best.1 {
        let _ = writeln!(
            log,
            "  decision {} site={} n={}",
            d.class.name(),
            d.site,
            d.n
        );
    }
    Some(ShrinkResult {
        seed,
        ordering,
        path,
        full_deviations,
        minimal_budget: hi,
        failure: best.0,
        decisions: best.1,
        log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_seeds_do_not_shrink() {
        let cfg = FuzzConfig::default();
        assert!(shrink_seed(0, &cfg).is_none());
        assert!(shrink_seed(1, &cfg).is_none());
    }
}
