//! The order-permutation seam: a seeded, deterministic source of
//! *same-instant ordering choices* for the concurrency fuzzer.
//!
//! The event loops ([`crate::sim::engine`], [`crate::sim::stream`]) are
//! deterministic: wherever several things happen "at the same instant" —
//! simultaneous kernel completions, a batch of due callbacks, a batch of
//! components entering the frontier together, the preemption victim scan,
//! victim re-entry — they fall back to a fixed canonical order (creation
//! order, heap seq, ascending index). Each such point is an *ambiguity*:
//! on real hardware the order is whatever the OS/driver race resolved to,
//! and the scheduler must produce an equivalent outcome for every
//! resolution.
//!
//! An [`OrderSeam`] threaded through the loops turns each ambiguity into
//! an explicit choice: the loop hands the seam the canonical batch, the
//! seam returns a (possibly) permuted order drawn from a seeded xorshift
//! stream. With no seam installed the loops run the canonical order,
//! byte-identically to the un-instrumented build. The seam also records
//! coverage — how many choice points each [`Ambiguity`] class hit, how
//! often the drawn order deviated from canonical, and a fingerprint set of
//! the distinct permutations exercised — which the fuzz report uses to
//! *prove* each class was genuinely permuted, and a bounded decision log
//! that the shrinker replays.
//!
//! Determinism contract: the permutation stream is a pure function of the
//! seed and the sequence of choice points the run presents. A run with
//! deviation budget `b` is identical to the unlimited run up through its
//! `b`-th deviating choice and canonical after — which is what lets the
//! shrinker binary-search the smallest deviation prefix that still fails.

/// One class of same-instant ambiguity the event loops admit.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Ambiguity {
    /// Simultaneous kernel-run completions: retirement order of runs
    /// finishing at the same instant.
    Completion,
    /// A same-instant batch of due events (callback firing, transfer
    /// completions, copy-engine completions, releases): inter-dispatch
    /// firing order. Events of one dispatch keep their relative order —
    /// a queue cannot reorder against itself.
    Callback,
    /// Tie-broken dispatch order: the frontier-entry order of components
    /// becoming ready at the same instant (initial readies, unblock
    /// batches, re-entries), which decides every bitwise rank/deadline
    /// tie-break downstream.
    DispatchTie,
    /// Preemption-vs-completion races: the order of the resident-victim
    /// candidate list handed to `Policy::preempt`, which decides which of
    /// several equally urgent victims is displaced.
    PreemptRace,
    /// Re-entry order after preemption: whether a displaced victim
    /// re-enters the frontier immediately or after the scheduler phase
    /// that displaced it finishes.
    Reentry,
    /// Fault-recovery races: whether an injected fault lands before or
    /// after completions due at its instant, which crash victim the
    /// recovery sweep walks first, and re-entry order of recovered work.
    FaultRace,
}

impl Ambiguity {
    /// Number of ambiguity classes.
    pub const COUNT: usize = 6;
    /// Every class, in report order.
    pub const ALL: [Ambiguity; Self::COUNT] = [
        Ambiguity::Completion,
        Ambiguity::Callback,
        Ambiguity::DispatchTie,
        Ambiguity::PreemptRace,
        Ambiguity::Reentry,
        Ambiguity::FaultRace,
    ];

    /// Dense index of this class (report/coverage array slot).
    pub fn idx(self) -> usize {
        self as usize
    }

    /// Stable report name.
    pub fn name(self) -> &'static str {
        match self {
            Ambiguity::Completion => "completion",
            Ambiguity::Callback => "callback",
            Ambiguity::DispatchTie => "dispatch-tie",
            Ambiguity::PreemptRace => "preempt-race",
            Ambiguity::Reentry => "reentry",
            Ambiguity::FaultRace => "fault-race",
        }
    }
}

/// Per-class choice-point accounting. A *site* is a choice point that
/// admitted at least two orders (a batch of one, or a batch whose every
/// element shares one group, is not a site). Every site resolves to either
/// the canonical order (`identity`) or a permuted one (`deviations`);
/// `identity ≥ 1 && deviations ≥ 1` therefore proves the run exercised at
/// least two distinct same-instant orderings of that class.
#[derive(Clone, Copy, Default, Debug, PartialEq, Eq)]
pub struct ClassCoverage {
    /// Choice points admitting ≥ 2 orders.
    pub sites: u64,
    /// Sites resolved to the canonical order.
    pub identity: u64,
    /// Sites resolved to a non-canonical order.
    pub deviations: u64,
}

/// One deviating choice, in the order taken — the shrinker's replay unit.
#[derive(Clone, Copy, Debug)]
pub struct Decision {
    /// Class of the choice point.
    pub class: Ambiguity,
    /// Global choice-point ordinal within the run (sites of every class
    /// share one counter, so the log reads as an event-order trace).
    pub site: u64,
    /// Batch size at the choice point (2 for a boolean flip).
    pub n: usize,
}

/// Cap on retained permutation fingerprints per class and on the decision
/// log — keeps seam memory bounded on deep runs without affecting the
/// permutation stream.
const FP_CAP: usize = 4096;
const DECISION_CAP: usize = 4096;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(FNV_PRIME)
}

/// Seeded deterministic order permuter — see the module docs.
pub struct OrderSeam {
    rng: u64,
    /// Remaining deviating choices allowed: `None` = unlimited, `Some(0)`
    /// = canonical orders only (coverage still recorded).
    budget: Option<u64>,
    next_site: u64,
    coverage: [ClassCoverage; Ambiguity::COUNT],
    fingerprints: [Vec<u64>; Ambiguity::COUNT],
    decisions: Vec<Decision>,
}

impl OrderSeam {
    /// Unlimited-deviation seam for `seed`.
    pub fn new(seed: u64) -> OrderSeam {
        OrderSeam::with_budget(seed, None)
    }

    /// Seam with a deviation budget: after `budget` deviating choices every
    /// later site resolves canonically. `Some(0)` never deviates — the
    /// canonical ordering driven through the seamed code path, used as
    /// ordering 0 of every workload and as the shrinker's lower bound.
    pub fn with_budget(seed: u64, budget: Option<u64>) -> OrderSeam {
        OrderSeam {
            rng: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1),
            budget,
            next_site: 0,
            coverage: [ClassCoverage::default(); Ambiguity::COUNT],
            fingerprints: std::array::from_fn(|_| Vec::new()),
            decisions: Vec::new(),
        }
    }

    fn next_u64(&mut self) -> u64 {
        // xorshift64* — the repo's standard deterministic stream.
        let mut x = self.rng;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Permute `items` freely (every element its own group).
    pub fn shuffle<T: Copy>(&mut self, class: Ambiguity, items: &mut [T]) {
        self.shuffle_grouped(class, items, |_| None);
    }

    /// Permute `items`, preserving the relative order of elements sharing a
    /// `key` (`None` = unconstrained singleton). This is the Callback-class
    /// constraint: events of one dispatch — a command-queue's own stream —
    /// may not reorder against each other, only against other dispatches'.
    /// A batch admitting a single order (len < 2, or all elements in one
    /// group) is passed through untouched and not counted as a site.
    pub fn shuffle_grouped<T: Copy>(
        &mut self,
        class: Ambiguity,
        items: &mut [T],
        key: impl Fn(&T) -> Option<usize>,
    ) {
        let n = items.len();
        if n < 2 {
            return;
        }
        let keys: Vec<Option<usize>> = items.iter().map(&key).collect();
        if keys[0].is_some() && keys.iter().all(|k| *k == keys[0]) {
            return;
        }
        let ci = class.idx();
        let site = self.next_site;
        self.next_site += 1;
        self.coverage[ci].sites += 1;
        let mut idx: Vec<usize> = (0..n).collect();
        if self.budget != Some(0) {
            for i in (1..n).rev() {
                let j = self.below(i + 1);
                idx.swap(i, j);
            }
            // Group fixup: grouped elements keep their canonical relative
            // order. Sorted (key, slot-position) zips against sorted
            // (key, original-index) — per-key counts agree, so the j-th
            // slot of a key receives its j-th member. No hash maps: the
            // fixup itself must be deterministic.
            let mut slots: Vec<(usize, usize)> = Vec::new();
            for (pos, &i) in idx.iter().enumerate() {
                if let Some(k) = keys[i] {
                    slots.push((k, pos));
                }
            }
            slots.sort_unstable();
            let mut members: Vec<(usize, usize)> = Vec::new();
            for (i, k) in keys.iter().enumerate() {
                if let Some(k) = *k {
                    members.push((k, i));
                }
            }
            members.sort_unstable();
            for (s, m) in slots.iter().zip(members.iter()) {
                idx[s.1] = m.1;
            }
        }
        let identity = idx.iter().enumerate().all(|(p, &i)| p == i);
        if identity {
            self.coverage[ci].identity += 1;
        } else {
            self.coverage[ci].deviations += 1;
            if let Some(b) = self.budget.as_mut() {
                *b = b.saturating_sub(1);
            }
            if self.decisions.len() < DECISION_CAP {
                self.decisions.push(Decision { class, site, n });
            }
        }
        let mut h = fnv(FNV_OFFSET, n as u64);
        for &i in &idx {
            h = fnv(h, i as u64);
        }
        if self.fingerprints[ci].len() < FP_CAP {
            self.fingerprints[ci].push(h);
        }
        let orig: Vec<T> = items.to_vec();
        for (p, &i) in idx.iter().enumerate() {
            items[p] = orig[i];
        }
    }

    /// A two-outcome choice point (`false` = canonical). Used for the
    /// Reentry class: defer a displaced victim's frontier re-entry to the
    /// end of the displacing scheduler phase instead of immediately.
    pub fn flip(&mut self, class: Ambiguity) -> bool {
        let ci = class.idx();
        let site = self.next_site;
        self.next_site += 1;
        self.coverage[ci].sites += 1;
        let deviate = self.budget != Some(0) && self.next_u64() & 1 == 1;
        let mut h = fnv(FNV_OFFSET, 2);
        if deviate {
            h = fnv(h, 1);
            h = fnv(h, 0);
            self.coverage[ci].deviations += 1;
            if let Some(b) = self.budget.as_mut() {
                *b = b.saturating_sub(1);
            }
            if self.decisions.len() < DECISION_CAP {
                self.decisions.push(Decision { class, site, n: 2 });
            }
        } else {
            h = fnv(h, 0);
            h = fnv(h, 1);
            self.coverage[ci].identity += 1;
        }
        if self.fingerprints[ci].len() < FP_CAP {
            self.fingerprints[ci].push(h);
        }
        deviate
    }

    /// Per-class coverage counters.
    pub fn coverage(&self) -> &[ClassCoverage; Ambiguity::COUNT] {
        &self.coverage
    }

    /// Raw permutation fingerprints recorded for `class` (unsorted, capped).
    pub fn fingerprints(&self, class: Ambiguity) -> &[u64] {
        &self.fingerprints[class.idx()]
    }

    /// Number of distinct permutations exercised for `class`.
    pub fn distinct_orderings(&self, class: Ambiguity) -> usize {
        let mut v = self.fingerprints[class.idx()].clone();
        v.sort_unstable();
        v.dedup();
        v.len()
    }

    /// The deviating choices taken, in order (capped log).
    pub fn decisions(&self) -> &[Decision] {
        &self.decisions
    }

    /// Total deviating choices across classes.
    pub fn deviations_total(&self) -> u64 {
        self.coverage.iter().map(|c| c.deviations).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_permutations() {
        for seed in [1u64, 7, 99] {
            let mut a = OrderSeam::new(seed);
            let mut b = OrderSeam::new(seed);
            for round in 0..50usize {
                let mut xs: Vec<usize> = (0..(round % 7 + 2)).collect();
                let mut ys = xs.clone();
                a.shuffle(Ambiguity::Completion, &mut xs);
                b.shuffle(Ambiguity::Completion, &mut ys);
                assert_eq!(xs, ys, "seed {seed} round {round}");
                assert_eq!(a.flip(Ambiguity::Reentry), b.flip(Ambiguity::Reentry));
            }
            assert_eq!(a.coverage(), b.coverage());
        }
    }

    #[test]
    fn zero_budget_is_canonical_and_still_counts() {
        let mut s = OrderSeam::with_budget(42, Some(0));
        let mut xs: Vec<u32> = (0..6).collect();
        s.shuffle(Ambiguity::Callback, &mut xs);
        assert_eq!(xs, (0..6).collect::<Vec<u32>>());
        assert!(!s.flip(Ambiguity::Reentry));
        let cov = s.coverage();
        assert_eq!(cov[Ambiguity::Callback.idx()].sites, 1);
        assert_eq!(cov[Ambiguity::Callback.idx()].identity, 1);
        assert_eq!(cov[Ambiguity::Callback.idx()].deviations, 0);
        assert_eq!(cov[Ambiguity::Reentry.idx()].sites, 1);
        assert_eq!(s.deviations_total(), 0);
        assert_eq!(s.distinct_orderings(Ambiguity::Callback), 1);
    }

    #[test]
    fn budget_limits_deviations_then_goes_canonical() {
        let mut s = OrderSeam::with_budget(3, Some(2));
        let mut devs = 0u64;
        for _ in 0..200 {
            let mut xs: Vec<usize> = (0..8).collect();
            s.shuffle(Ambiguity::DispatchTie, &mut xs);
            if xs != (0..8).collect::<Vec<usize>>() {
                devs += 1;
            }
        }
        assert_eq!(devs, 2, "exactly the budgeted deviations occur");
        assert_eq!(s.deviations_total(), 2);
        assert_eq!(s.decisions().len(), 2);
    }

    /// A budgeted run must replay the unlimited run's deviation prefix:
    /// identical permutations up through the budget'th deviation.
    #[test]
    fn budget_run_is_a_prefix_of_the_unlimited_run() {
        let seed = 77;
        let mut full = OrderSeam::new(seed);
        let mut cut = OrderSeam::with_budget(seed, Some(3));
        let mut diverged = false;
        for _ in 0..100 {
            let mut xs: Vec<usize> = (0..5).collect();
            let mut ys = xs.clone();
            full.shuffle(Ambiguity::Completion, &mut xs);
            cut.shuffle(Ambiguity::Completion, &mut ys);
            if cut.deviations_total() < 3 && !diverged {
                assert_eq!(xs, ys, "identical until the budget is spent");
            }
            if xs != ys {
                diverged = true;
            }
        }
        assert_eq!(cut.deviations_total(), 3);
        assert!(full.deviations_total() > 3);
    }

    #[test]
    fn grouped_shuffle_preserves_intra_group_order() {
        let mut s = OrderSeam::new(11);
        for round in 0..100 {
            // (group, ordinal-within-group) pairs; Nones are singletons.
            let mut xs: Vec<(usize, usize)> = vec![
                (0, 0),
                (0, 1),
                (1, 0),
                (0, 2),
                (1, 1),
                (2, 0),
                (3, 0),
            ];
            s.shuffle_grouped(Ambiguity::Callback, &mut xs, |&(g, _)| {
                (g < 2).then_some(g)
            });
            for g in 0..2usize {
                let ords: Vec<usize> =
                    xs.iter().filter(|&&(x, _)| x == g).map(|&(_, o)| o).collect();
                let sorted = {
                    let mut v = ords.clone();
                    v.sort_unstable();
                    v
                };
                assert_eq!(ords, sorted, "round {round} group {g} order broken");
            }
            let mut all = xs.clone();
            all.sort_unstable();
            assert_eq!(
                all,
                vec![(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (2, 0), (3, 0)],
                "no element lost or duplicated"
            );
        }
        let cov = s.coverage()[Ambiguity::Callback.idx()];
        assert_eq!(cov.sites, 100);
        assert!(cov.deviations > 0, "free elements must actually move");
        assert!(s.distinct_orderings(Ambiguity::Callback) > 1);
    }

    #[test]
    fn single_order_batches_are_not_sites() {
        let mut s = OrderSeam::new(5);
        let mut one = [7u32];
        s.shuffle(Ambiguity::Completion, &mut one);
        let mut same_group = [(9usize, 0usize), (9, 1), (9, 2)];
        s.shuffle_grouped(Ambiguity::Callback, &mut same_group, |&(g, _)| Some(g));
        assert_eq!(same_group, [(9, 0), (9, 1), (9, 2)]);
        assert_eq!(s.coverage()[Ambiguity::Completion.idx()].sites, 0);
        assert_eq!(s.coverage()[Ambiguity::Callback.idx()].sites, 0);
    }
}
