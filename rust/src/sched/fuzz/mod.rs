//! Deterministic concurrency fuzzer for the scheduler core.
//!
//! Production schedulers break on the orderings nobody wrote a test for:
//! two kernels completing at the same instant, callbacks firing in a
//! different interleaving than the dispatches that armed them, a
//! preemption racing a completion. The event loops in [`crate::sim`] pick
//! *one* canonical order for each of those ambiguities; this module
//! replays seeded workloads through the same loops while permuting every
//! same-instant choice the loops admit, and checks ordering-independent
//! invariants across all permutations of one workload:
//!
//! * every run completes — no lost or duplicated dispatch (a duplicate
//!   trips the engines' own debug assertions, a loss shows up as a
//!   component that never finishes);
//! * every component finishes at a finite instant no earlier than its
//!   release;
//! * the makespan stays within a provable envelope — at least the
//!   min-device critical path, at most a contention- and
//!   preemption-scaled multiple of the total serial work;
//! * no preemption ping-pong: displacements per component are bounded;
//! * the streaming path drains every admitted request and ends with zero
//!   live components;
//! * replaying any ordering is bit-identical (same makespan bits, same
//!   decision log).
//!
//! The pieces: [`seam`] (the [`OrderSeam`] choice-point the event loops
//! consult, with per-class coverage counters), [`gen`] (seeded workload
//! generation with crafted always-covering shapes), [`oracle`] (the
//! [`crate::sched::SchedState`] event fuzzer with a from-scratch rebuild
//! oracle), [`shrink`] (minimal-deviation reproducers), and this driver,
//! which the `pyschedcl fuzz` subcommand and the committed
//! `ci/fuzz_corpus/` regression seeds call into.

pub mod gen;
pub mod oracle;
pub mod seam;
pub mod shrink;

pub use gen::{engine_workload, fault_plan, stream_plan, PolicyKind, StreamPlan, UnitPlan, Workload};
pub use oracle::{fuzz_state_events, OracleStats};
pub use seam::{Ambiguity, ClassCoverage, Decision, OrderSeam};
pub use shrink::{shrink_seed, FailingRun, ShrinkResult};

use crate::cost::{CostModel, PaperCost};
use crate::error::Error;
use crate::fault::{FaultKind, FaultPlan};
use crate::graph::{Dag, Partition};
use crate::json::Json;
use crate::platform::Platform;
use crate::sim::{
    simulate_served_fuzzed, AdmitUnit, MemberSpec, PumpStop, SimConfig, SimResult, StreamSim,
    Template,
};
use std::fmt::Write as _;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

const EPS: f64 = 1e-9;

/// Tunables for a fuzz run.
#[derive(Clone, Copy, Debug)]
pub struct FuzzConfig {
    /// Orderings explored per seed. Ordering 0 is always the canonical
    /// (identity-seam) order; the rest permute freely.
    pub orderings: usize,
    /// Deviation budget for orderings ≥ 1 (`None` = unlimited); the
    /// shrinker binary-searches this.
    pub budget: Option<u64>,
    /// Event count for the [`SchedState`](crate::sched::SchedState)
    /// rebuild oracle run folded into each seed.
    pub oracle_steps: usize,
}

impl Default for FuzzConfig {
    fn default() -> FuzzConfig {
        FuzzConfig {
            orderings: 4,
            budget: None,
            oracle_steps: 120,
        }
    }
}

/// Seam seed for ordering `o` of workload `seed`: a splitmix-style spread
/// so consecutive orderings get unrelated permutation streams.
fn seam_seed(seed: u64, ordering: usize) -> u64 {
    seed ^ (ordering as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xA076_1D64_78BD_642F
}

/// Ordering 0 is the identity seam (canonical order through the seamed
/// code paths, so choice sites still count toward coverage).
fn ordering_budget(cfg: &FuzzConfig, ordering: usize) -> Option<u64> {
    if ordering == 0 {
        Some(0)
    } else {
        cfg.budget
    }
}

// --------------------------------------------------------------- fingerprints

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(FNV_PRIME)
}

/// Bit-level digest of one run: makespan and finish instants by their
/// exact bits plus the full seam decision log. Two runs of the same
/// (seed, ordering) must produce equal fingerprints or the fuzzer itself
/// is non-deterministic.
fn run_fingerprint(makespan: f64, preemptions: usize, finishes: &[f64], seam: &OrderSeam) -> u64 {
    let mut h = FNV_OFFSET;
    h = fnv(h, makespan.to_bits());
    h = fnv(h, preemptions as u64);
    for &f in finishes {
        h = fnv(h, f.to_bits());
    }
    for d in seam.decisions() {
        h = fnv(h, d.class.idx() as u64);
        h = fnv(h, d.site);
        h = fnv(h, d.n as u64);
    }
    h
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

// ------------------------------------------------------------------ path runs

/// Outcome of one (workload, ordering) run through one execution path.
pub(crate) struct PathRun {
    pub(crate) failure: Option<String>,
    pub(crate) makespan: f64,
    pub(crate) preemptions: usize,
    pub(crate) coverage: [ClassCoverage; Ambiguity::COUNT],
    pub(crate) distinct: [usize; Ambiguity::COUNT],
    pub(crate) deviations: u64,
    pub(crate) decisions: Vec<Decision>,
    pub(crate) fingerprint: u64,
}

impl PathRun {
    fn failed(msg: String) -> PathRun {
        PathRun {
            failure: Some(msg),
            makespan: f64::NAN,
            preemptions: 0,
            coverage: [ClassCoverage::default(); Ambiguity::COUNT],
            distinct: [0; Ambiguity::COUNT],
            deviations: 0,
            decisions: Vec::new(),
            fingerprint: 0,
        }
    }

    fn absorb_seam(&mut self, seam: &OrderSeam) {
        self.coverage = *seam.coverage();
        for (i, &a) in Ambiguity::ALL.iter().enumerate() {
            self.distinct[i] = seam.distinct_orderings(a);
        }
        self.deviations = seam.deviations_total();
        self.decisions = seam.decisions().to_vec();
    }

    fn line(&self) -> String {
        match &self.failure {
            Some(f) => format!("FAIL ({f})"),
            None => format!(
                "makespan={:.9e} preemptions={} deviations={}",
                self.makespan, self.preemptions, self.deviations
            ),
        }
    }
}

/// Run the engine path of `seed` under one permuted ordering.
pub(crate) fn run_engine_path(seed: u64, ordering: usize, budget: Option<u64>) -> PathRun {
    let wl = engine_workload(seed);
    let mut policy = wl.policy.build();
    let mut seam = OrderSeam::with_budget(seam_seed(seed, ordering), budget);
    let res = catch_unwind(AssertUnwindSafe(|| {
        simulate_served_fuzzed(
            &wl.dag,
            &wl.partition,
            &wl.platform,
            &PaperCost,
            policy.as_mut(),
            &wl.cfg,
            &wl.meta,
            &mut seam,
        )
    }));
    let mut run = match res {
        Err(p) => PathRun::failed(format!("engine panicked: {}", panic_message(p.as_ref()))),
        Ok(Err(e)) => PathRun::failed(format!("engine error: {e}")),
        Ok(Ok(sim)) => PathRun {
            failure: check_engine_invariants(&wl, &sim).err(),
            fingerprint: run_fingerprint(
                sim.makespan,
                sim.preemptions,
                &sim.component_finish,
                &seam,
            ),
            makespan: sim.makespan,
            preemptions: sim.preemptions,
            coverage: [ClassCoverage::default(); Ambiguity::COUNT],
            distinct: [0; Ambiguity::COUNT],
            deviations: 0,
            decisions: Vec::new(),
        },
    };
    run.absorb_seam(&seam);
    run
}

/// Run the streaming path of `seed` under one permuted ordering.
pub(crate) fn run_stream_path(seed: u64, ordering: usize, budget: Option<u64>) -> PathRun {
    let StreamPlan {
        label: _,
        dag,
        partition,
        platform,
        cfg,
        policy: pk,
        units,
    } = stream_plan(seed);
    let tmpl = Arc::new((dag, partition));
    let ncomp = tmpl.1.components.len();
    let n_units = units.len();
    let max_release = units.iter().map(|u| u.release).fold(0.0, f64::max);
    let plan = fault_plan(seed, platform.devices.len());
    let empty_dag = Dag::default();
    let empty_part = Partition {
        components: Vec::new(),
        assignment: Vec::new(),
    };
    let mut policy = pk.build();
    let res = catch_unwind(AssertUnwindSafe(
        || -> std::result::Result<(f64, usize, Vec<f64>, usize, usize, OrderSeam), String> {
            let mut sim = StreamSim::new(
                &empty_dag,
                &empty_part,
                &platform,
                &PaperCost,
                policy.as_mut(),
                &cfg,
            )
            .map_err(|e| format!("stream construction: {e}"))?;
            sim.install_seam(OrderSeam::with_budget(seam_seed(seed, ordering), budget));
            if let Some(p) = &plan {
                sim.install_faults(p)
                    .map_err(|e| format!("install faults: {e}"))?;
            }
            for (i, u) in units.iter().enumerate() {
                sim.admit(AdmitUnit {
                    tmpl: Template::Single(tmpl.clone()),
                    release: u.release,
                    members: vec![MemberSpec {
                        id: i,
                        arrival: u.release,
                        deadline: u.deadline,
                        priority: u.priority,
                        comps: 0..ncomp,
                    }],
                })
                .map_err(|e| format!("admit unit {i}: {e}"))?;
            }
            let stop = sim.pump(f64::INFINITY).map_err(|e| format!("pump: {e}"))?;
            if stop != PumpStop::Idle {
                return Err(format!("pump stopped at {stop:?} before going idle"));
            }
            let mut fin = Vec::new();
            sim.drain_finished_into(&mut fin);
            if fin.len() != n_units {
                return Err(format!(
                    "{} of {n_units} requests drained (lost request)",
                    fin.len()
                ));
            }
            if sim.live_components() != 0 {
                return Err(format!(
                    "{} live components after full drain",
                    sim.live_components()
                ));
            }
            fin.sort_by_key(|f| f.id);
            for w in fin.windows(2) {
                if w[0].id == w[1].id {
                    return Err(format!("request {} surfaced twice (duplicated)", w[0].id));
                }
            }
            for f in &fin {
                if !f.finish.is_finite() || f.finish + EPS < f.release {
                    return Err(format!(
                        "request {} finished at {:.6} vs release {:.6}",
                        f.id, f.finish, f.release
                    ));
                }
            }
            // Fault-recovery bookkeeping: retries stay within the plan's
            // budget (a shed record carries the budget-busting charge),
            // and without a plan no fault accounting may appear at all.
            match &plan {
                Some(p) => {
                    for f in &fin {
                        let cap = if f.shed { p.retry_budget + 1 } else { p.retry_budget };
                        if f.retries > cap {
                            return Err(format!(
                                "request {} consumed {} retries (budget {}, shed {})",
                                f.id, f.retries, p.retry_budget, f.shed
                            ));
                        }
                    }
                }
                None => {
                    if let Some(f) = fin.iter().find(|f| f.shed || f.retries != 0) {
                        return Err(format!(
                            "request {} shows fault bookkeeping with no plan installed",
                            f.id
                        ));
                    }
                }
            }
            let finishes: Vec<f64> = fin.iter().map(|f| f.finish).collect();
            let shed = sim.shed();
            let displaced = sim.fault_displacements();
            let seam = sim.take_seam().expect("seam was installed");
            Ok((sim.makespan(), sim.preemptions(), finishes, shed, displaced, seam))
        },
    ));
    match res {
        Err(p) => PathRun::failed(format!("stream panicked: {}", panic_message(p.as_ref()))),
        Ok(Err(e)) => PathRun::failed(e),
        Ok(Ok((makespan, preemptions, finishes, shed, displaced, seam))) => {
            let mut failure = None;
            let lo = max_release + makespan_lower_bound(&tmpl.0, &platform);
            // A shed request never ran to completion, so the critical-path
            // floor only binds when everything was actually served.
            if shed == 0 && makespan + EPS < lo {
                failure = Some(format!(
                    "makespan {makespan:.6} below the provable floor {lo:.6}"
                ));
            }
            let mut hi = makespan_envelope(
                &tmpl.0,
                &platform,
                &cfg,
                max_release,
                preemptions + displaced,
                n_units,
            );
            if let Some(p) = &plan {
                let (scale, add) = fault_allowance(p, n_units);
                hi = hi * scale + add;
            }
            if makespan > hi {
                failure = Some(format!(
                    "makespan {makespan:.6} above the envelope {hi:.6} \
                     (preemptions {preemptions}, fault displacements {displaced})"
                ));
            }
            let mut run = PathRun {
                failure,
                fingerprint: run_fingerprint(makespan, preemptions, &finishes, &seam),
                makespan,
                preemptions,
                coverage: [ClassCoverage::default(); Ambiguity::COUNT],
                distinct: [0; Ambiguity::COUNT],
                deviations: 0,
                decisions: Vec::new(),
            };
            run.absorb_seam(&seam);
            run
        }
    }
}

// ------------------------------------------------------------ invariant math

/// Provable makespan floor: the DAG's critical path with every kernel at
/// its fastest device, ignoring transfers, overheads, and contention.
fn makespan_lower_bound(dag: &Dag, platform: &Platform) -> f64 {
    let w: Vec<f64> = dag
        .kernels
        .iter()
        .map(|k| {
            platform
                .devices
                .iter()
                .map(|d| PaperCost.exec_time(k, d))
                .fold(f64::INFINITY, f64::min)
        })
        .collect();
    crate::graph::rank::critical_path(dag, &w)
}

/// Provable makespan ceiling: even a worst-case schedule cannot exceed
/// running all `copies` of the DAG serially at the slowest device under
/// the worst contention share, re-doing the work once per preemption,
/// plus generous per-kernel overhead and a constant slack. Deliberately
/// loose — it catches runaway schedules (re-execution loops, lost-wakeup
/// stalls resolved by a later unrelated event), not small regressions.
fn makespan_envelope(
    dag: &Dag,
    platform: &Platform,
    cfg: &SimConfig,
    max_release: f64,
    preemptions: usize,
    copies: usize,
) -> f64 {
    let serial: f64 = dag
        .kernels
        .iter()
        .map(|k| {
            platform
                .devices
                .iter()
                .map(|d| PaperCost.exec_time(k, d))
                .fold(0.0, f64::max)
        })
        .sum();
    let xfer: f64 = dag
        .buffers
        .iter()
        .map(|b| {
            platform
                .devices
                .iter()
                .map(|d| platform.transfer_time(d.id, b.size_bytes))
                .fold(0.0, f64::max)
        })
        .sum();
    let nk = dag.kernels.len() as f64 * copies as f64;
    let over = nk
        * 8.0
        * (platform.enqueue_overhead + platform.callback_latency + platform.wait_latency);
    let eff = cfg.contention_efficiency.clamp(0.25, 1.0);
    let per_copy = (copies as f64) * (serial / eff + xfer) + over;
    max_release + (1.0 + preemptions as f64) * per_copy * 4.0 + 1.0
}

/// How much an installed fault plan is allowed to widen the makespan
/// envelope: a slowdown scales every kernel by up to `1/factor`, wedges
/// add their stall outright, and each request may burn the full
/// exponential-backoff series before its last retry.
fn fault_allowance(plan: &FaultPlan, n_units: usize) -> (f64, f64) {
    let mut scale = 1.0f64;
    let mut add = 0.0f64;
    for e in &plan.events {
        match e.kind {
            FaultKind::Wedge { dur } => add += dur,
            FaultKind::Slowdown { factor } => scale = scale.max(1.0 / factor),
            FaultKind::Crash => {}
        }
    }
    add += n_units as f64 * plan.backoff_base * (1u64 << (plan.retry_budget.min(20) + 1)) as f64;
    (scale, add)
}

fn check_engine_invariants(wl: &Workload, sim: &SimResult) -> std::result::Result<(), String> {
    let ncomp = wl.partition.components.len();
    if sim.component_finish.len() != ncomp {
        return Err(format!(
            "{} finish entries for {ncomp} components",
            sim.component_finish.len()
        ));
    }
    let mut max_release: f64 = 0.0;
    for (c, m) in wl.meta.iter().enumerate() {
        max_release = max_release.max(m.release);
        let f = sim.component_finish[c];
        if !f.is_finite() {
            return Err(format!("component {c} never finished (lost dispatch)"));
        }
        if f + EPS < m.release {
            return Err(format!(
                "component {c} finished at {f:.6} before its release {:.6}",
                m.release
            ));
        }
    }
    let lo = makespan_lower_bound(&wl.dag, &wl.platform).max(max_release);
    if sim.makespan + EPS < lo {
        return Err(format!(
            "makespan {:.6} below the provable floor {lo:.6}",
            sim.makespan
        ));
    }
    let hi = makespan_envelope(&wl.dag, &wl.platform, &wl.cfg, max_release, sim.preemptions, 1);
    if sim.makespan > hi {
        return Err(format!(
            "makespan {:.6} above the envelope {hi:.6} (preemptions {})",
            sim.makespan, sim.preemptions
        ));
    }
    // No preemption ping-pong: displacements per victim are bounded. The
    // engine stamps one `preempt c{victim}` span per displacement.
    let mut per = vec![0usize; ncomp];
    for span in &sim.trace.spans {
        if let Some(v) = span
            .label
            .strip_prefix("preempt c")
            .and_then(|rest| rest.parse::<usize>().ok())
        {
            if v < ncomp {
                per[v] += 1;
            }
        }
    }
    let bound = 2 * ncomp + 4;
    for (c, &n) in per.iter().enumerate() {
        if n > bound {
            return Err(format!(
                "component {c} displaced {n} times (ping-pong; bound {bound})"
            ));
        }
    }
    Ok(())
}

// ------------------------------------------------------------------- reports

/// Everything one fuzz seed produced: failures, aggregated coverage, and
/// a deterministic replay log (same seed + config ⇒ byte-identical log).
pub struct SeedReport {
    pub seed: u64,
    pub failures: Vec<String>,
    pub coverage: [ClassCoverage; Ambiguity::COUNT],
    pub distinct: [usize; Ambiguity::COUNT],
    pub log: String,
}

impl SeedReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

fn merge_run(
    cov: &mut [ClassCoverage; Ambiguity::COUNT],
    distinct: &mut [usize; Ambiguity::COUNT],
    run: &PathRun,
) {
    for i in 0..Ambiguity::COUNT {
        cov[i].sites += run.coverage[i].sites;
        cov[i].identity += run.coverage[i].identity;
        cov[i].deviations += run.coverage[i].deviations;
        distinct[i] = distinct[i].max(run.distinct[i]);
    }
}

/// Fuzz one seed: both execution paths under every ordering, a replay
/// determinism check, and one state-oracle run.
pub fn run_seed(seed: u64, cfg: &FuzzConfig) -> SeedReport {
    let mut rep = SeedReport {
        seed,
        failures: Vec::new(),
        coverage: [ClassCoverage::default(); Ambiguity::COUNT],
        distinct: [0; Ambiguity::COUNT],
        log: String::new(),
    };
    let orderings = cfg.orderings.max(1);
    let _ = writeln!(rep.log, "seed {seed}");

    let _ = writeln!(rep.log, "  engine: {}", engine_workload(seed).label);
    let mut engine_fp = 0u64;
    for o in 0..orderings {
        let run = run_engine_path(seed, o, ordering_budget(cfg, o));
        merge_run(&mut rep.coverage, &mut rep.distinct, &run);
        let _ = writeln!(rep.log, "    ordering {o}: {}", run.line());
        if let Some(f) = &run.failure {
            rep.failures.push(format!("engine ordering {o}: {f}"));
        }
        engine_fp = run.fingerprint;
    }

    let sp = stream_plan(seed);
    let _ = writeln!(rep.log, "  stream: {}", sp.label);
    if let Some(p) = fault_plan(seed, sp.platform.devices.len()) {
        let _ = writeln!(
            rep.log,
            "    faults: {} event(s), retry budget {}, policy {}",
            p.events.len(),
            p.retry_budget,
            p.shed_policy.name()
        );
    }
    let mut stream_fp = 0u64;
    for o in 0..orderings {
        let run = run_stream_path(seed, o, ordering_budget(cfg, o));
        merge_run(&mut rep.coverage, &mut rep.distinct, &run);
        let _ = writeln!(rep.log, "    ordering {o}: {}", run.line());
        if let Some(f) = &run.failure {
            rep.failures.push(format!("stream ordering {o}: {f}"));
        }
        stream_fp = run.fingerprint;
    }

    // Determinism: replaying the last ordering must be bit-identical
    // (same makespan bits, same decision log).
    let o = orderings - 1;
    let budget = ordering_budget(cfg, o);
    let engine_det = run_engine_path(seed, o, budget).fingerprint == engine_fp;
    let stream_det = run_stream_path(seed, o, budget).fingerprint == stream_fp;
    let _ = writeln!(
        rep.log,
        "  determinism: engine {} stream {}",
        if engine_det { "ok" } else { "DIVERGED" },
        if stream_det { "ok" } else { "DIVERGED" },
    );
    if !engine_det {
        rep.failures
            .push(format!("engine ordering {o} replay diverged (non-deterministic)"));
    }
    if !stream_det {
        rep.failures
            .push(format!("stream ordering {o} replay diverged (non-deterministic)"));
    }

    match fuzz_state_events(seed, cfg.oracle_steps) {
        Ok(st) => {
            let _ = writeln!(
                rep.log,
                "  oracle: steps={} rebuilds={} compactions={} ok",
                st.steps, st.rebuilds, st.compactions
            );
        }
        Err(e) => {
            let _ = writeln!(rep.log, "  oracle: FAIL ({e})");
            rep.failures.push(format!("state oracle: {e}"));
        }
    }

    for (i, a) in Ambiguity::ALL.iter().enumerate() {
        let c = rep.coverage[i];
        let _ = writeln!(
            rep.log,
            "  coverage {:<12} sites={} identity={} deviations={} distinct={}",
            a.name(),
            c.sites,
            c.identity,
            c.deviations,
            rep.distinct[i]
        );
    }
    let _ = writeln!(
        rep.log,
        "  seed {seed}: {}",
        if rep.ok() { "ok" } else { "FAIL" }
    );
    rep
}

/// Aggregate over a seed range.
pub struct FuzzSummary {
    pub seeds: u64,
    /// First failure message per failing seed.
    pub failures: Vec<(u64, String)>,
    pub coverage: [ClassCoverage; Ambiguity::COUNT],
    pub distinct: [usize; Ambiguity::COUNT],
}

impl FuzzSummary {
    /// Ambiguity classes *without* proven ordering diversity. A class is
    /// proven when at least one choice site kept the canonical order and
    /// at least one deviated — i.e. ≥ 2 distinct same-instant orderings
    /// were actually executed, not just reachable.
    pub fn unproven_classes(&self) -> Vec<&'static str> {
        Ambiguity::ALL
            .iter()
            .enumerate()
            .filter(|&(i, _)| self.coverage[i].identity < 1 || self.coverage[i].deviations < 1)
            .map(|(_, a)| a.name())
            .collect()
    }

    pub fn ok(&self) -> bool {
        self.failures.is_empty() && self.unproven_classes().is_empty()
    }

    /// Human-readable coverage table plus failures, deterministic.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "fuzz: {} seeds, {} failing",
            self.seeds,
            self.failures.len()
        );
        let _ = writeln!(
            s,
            "{:<14} {:>8} {:>10} {:>11} {:>9}",
            "class", "sites", "identity", "deviations", "distinct"
        );
        for (i, a) in Ambiguity::ALL.iter().enumerate() {
            let c = self.coverage[i];
            let _ = writeln!(
                s,
                "{:<14} {:>8} {:>10} {:>11} {:>9}",
                a.name(),
                c.sites,
                c.identity,
                c.deviations,
                self.distinct[i]
            );
        }
        for (seed, f) in &self.failures {
            let _ = writeln!(s, "FAIL seed {seed}: {f}");
        }
        s
    }
}

// ------------------------------------------------------------------- corpus

/// One committed corpus regression seed:
/// `{"seed": N, "orderings": K, "note": "..."}`.
pub struct CorpusSeed {
    pub path: std::path::PathBuf,
    pub seed: u64,
    pub orderings: usize,
    pub note: String,
}

fn parse_corpus_seed(text: &str) -> crate::error::Result<(u64, usize, String)> {
    let json = Json::parse(text)?;
    let seed = json
        .field("seed")?
        .as_u64()
        .ok_or_else(|| Error::Io("corpus field 'seed' is not a u64".into()))?;
    let orderings = json
        .field("orderings")?
        .as_usize()
        .ok_or_else(|| Error::Io("corpus field 'orderings' is not a usize".into()))?;
    let note = json
        .get("note")
        .and_then(|n| n.as_str())
        .unwrap_or("")
        .to_string();
    Ok((seed, orderings, note))
}

/// Load every committed `*.json` regression seed in `dir`, sorted by
/// path — the `pyschedcl fuzz --corpus DIR` loader, in the library so the
/// error contract is testable. Every failure is a typed [`Error::Io`]: an
/// unreadable directory is `cannot read corpus dir {dir}: {e}` and a
/// directory holding no seeds is `no *.json corpus seeds in {dir}`.
pub fn load_corpus_seeds(dir: &str) -> crate::error::Result<Vec<CorpusSeed>> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| Error::Io(format!("cannot read corpus dir {dir}: {e}")))?;
    let mut paths: Vec<std::path::PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(Error::Io(format!("no *.json corpus seeds in {dir}")));
    }
    let mut seeds = Vec::with_capacity(paths.len());
    for p in paths {
        let text = std::fs::read_to_string(&p)
            .map_err(|e| Error::Io(format!("cannot read {}: {e}", p.display())))?;
        let (seed, orderings, note) =
            parse_corpus_seed(&text).map_err(|e| Error::Io(format!("{}: {e}", p.display())))?;
        seeds.push(CorpusSeed {
            path: p,
            seed,
            orderings,
            note,
        });
    }
    Ok(seeds)
}

/// Fuzz `count` seeds starting at `start`, feeding each finished
/// [`SeedReport`] to `per_seed` (print it, collect it, ignore it).
pub fn run_many(
    start: u64,
    count: u64,
    cfg: &FuzzConfig,
    mut per_seed: impl FnMut(&SeedReport),
) -> FuzzSummary {
    let mut sum = FuzzSummary {
        seeds: count,
        failures: Vec::new(),
        coverage: [ClassCoverage::default(); Ambiguity::COUNT],
        distinct: [0; Ambiguity::COUNT],
    };
    for seed in start..start.saturating_add(count) {
        let rep = run_seed(seed, cfg);
        for i in 0..Ambiguity::COUNT {
            sum.coverage[i].sites += rep.coverage[i].sites;
            sum.coverage[i].identity += rep.coverage[i].identity;
            sum.coverage[i].deviations += rep.coverage[i].deviations;
            sum.distinct[i] = sum.distinct[i].max(rep.distinct[i]);
        }
        if let Some(f) = rep.failures.first() {
            sum.failures.push((seed, f.clone()));
        }
        per_seed(&rep);
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tentpole acceptance invariant in test form: the crafted shapes
    /// plus a few random seeds execute ≥ 2 distinct same-instant
    /// orderings in *every* ambiguity class, and nothing fails.
    #[test]
    fn crafted_seeds_prove_every_ambiguity_class() {
        let cfg = FuzzConfig {
            orderings: 8,
            ..FuzzConfig::default()
        };
        let sum = run_many(0, 8, &cfg, |_| {});
        assert!(
            sum.failures.is_empty(),
            "fuzz failures:\n{}",
            sum.render()
        );
        assert!(
            sum.unproven_classes().is_empty(),
            "unproven classes {:?}\n{}",
            sum.unproven_classes(),
            sum.render()
        );
        // The chaos seam specifically: the sweep must have executed at
        // least two distinct same-instant orderings of fault-vs-completion
        // races, not merely reached the choice sites.
        assert!(
            sum.distinct[Ambiguity::FaultRace.idx()] >= 2,
            "fault-race never diversified\n{}",
            sum.render()
        );
    }

    /// Crafted seeds stay fault-free (their coverage guarantees must not
    /// depend on chaos) and fault plans are pure functions of the seed.
    #[test]
    fn fault_plans_are_deterministic_and_spare_crafted_seeds() {
        assert!(fault_plan(0, 3).is_none());
        assert!(fault_plan(1, 3).is_none());
        for seed in [2u64, 3, 6, 7] {
            let a = fault_plan(seed, 3).expect("fault seed has a plan");
            let b = fault_plan(seed, 3).unwrap();
            assert_eq!(a.events.len(), b.events.len());
            for (x, y) in a.events.iter().zip(&b.events) {
                assert_eq!(x.device, y.device);
                assert_eq!(x.at.to_bits(), y.at.to_bits());
            }
            assert!(a.events.iter().all(|e| e.device < 3));
        }
    }

    #[test]
    fn corpus_loading_missing_dir_is_a_typed_io_error() {
        let dir = "/nonexistent/pyschedcl-fuzz-corpus";
        let e = load_corpus_seeds(dir).unwrap_err();
        match e {
            Error::Io(m) => assert!(
                m.starts_with(&format!("cannot read corpus dir {dir}: ")),
                "wrong message: {m}"
            ),
            other => panic!("expected Error::Io, got {other}"),
        }
    }

    #[test]
    fn corpus_loading_empty_dir_is_a_typed_io_error() {
        let dir = std::env::temp_dir().join(format!(
            "pyschedcl-empty-corpus-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let dir_s = dir.to_str().unwrap().to_string();
        let e = load_corpus_seeds(&dir_s).unwrap_err();
        std::fs::remove_dir_all(&dir).ok();
        match e {
            Error::Io(m) => assert_eq!(m, format!("no *.json corpus seeds in {dir_s}")),
            other => panic!("expected Error::Io, got {other}"),
        }
    }

    #[test]
    fn fuzz_reports_are_deterministic() {
        let cfg = FuzzConfig::default();
        let a = run_seed(3, &cfg);
        let b = run_seed(3, &cfg);
        assert_eq!(a.log, b.log, "same seed must produce a byte-identical log");
        assert!(a.ok(), "{}", a.log);
    }

    /// Ordering 0 (identity seam) tracks the unseamed serving path: same
    /// preemption count, same makespan up to the ≤1e-9 retire-batching
    /// residue the fuzz path's two-phase retirement introduces.
    #[test]
    fn canonical_ordering_matches_unseamed_engine() {
        for seed in [0u64, 1, 5] {
            let wl = engine_workload(seed);
            let mut policy = wl.policy.build();
            let base = crate::sim::simulate_served(
                &wl.dag,
                &wl.partition,
                &wl.platform,
                &PaperCost,
                policy.as_mut(),
                &wl.cfg,
                &wl.meta,
            )
            .unwrap();
            let run = run_engine_path(seed, 0, Some(0));
            assert!(run.failure.is_none(), "seed {seed}: {:?}", run.failure);
            let tol = 1e-6 * base.makespan.abs().max(1e-3);
            assert!(
                (run.makespan - base.makespan).abs() <= tol,
                "seed {seed}: canonical fuzz makespan {} vs engine {}",
                run.makespan,
                base.makespan
            );
            assert_eq!(run.preemptions, base.preemptions, "seed {seed}");
        }
    }

    /// The crafted preemption shape actually preempts — the PreemptRace
    /// and Reentry guarantees rest on it.
    #[test]
    fn preempt_storm_preempts() {
        let run = run_engine_path(1, 0, Some(0));
        assert!(run.failure.is_none(), "{:?}", run.failure);
        assert!(run.preemptions >= 1, "crafted shape 1 must preempt");
    }
}
