//! The pluggable `select` routine of Algorithm 1, redesigned around the
//! incrementally maintained [`SchedState`] (PR 5).
//!
//! A policy no longer receives a freshly materialized frontier snapshot to
//! scan — it *queries* the indexed scheduler state ([`SchedState`]'s
//! per-device-type rank buckets, deadline heap, and fallback heap), making
//! every shipped policy's `select` O(log frontier) instead of O(frontier).
//! The pre-PR-5 view-based trait and policies are preserved verbatim in
//! [`super::reference`] and proven decision-identical by
//! `tests/prop_policy_equiv.rs` plus the bit-identical `SimResult`
//! equivalence suite (`tests/integration_sim_equiv.rs`).
//!
//! Writing a new policy: implement [`Policy::select`] against the
//! [`SchedState`] query API — `rank_head` / `rank_head_placeable` for the
//! rank-ordered frontier, `urgency_head` for the EDF order,
//! `first_available_of` / `least_loaded_available_of` for device choice,
//! plus the raw `est_free` / `device_load` / `deadline` / `priority`
//! fields. `select` may mutate the state only through its query methods
//! (lazy heap pruning); the engines apply the returned decision via the
//! event API.

use super::state::SchedState;
use crate::cost::CostModel;
use crate::graph::{Dag, Partition};
use crate::platform::{Device, DeviceId, Platform};

/// Optimistic solo-seconds estimate of one whole application — a true
/// **lower bound** on its makespan. Components are independent (they could
/// run fully in parallel) and a component's kernels overlap across the
/// device's command queues, so the only schedule-independent floor is the
/// single longest kernel anywhere in the application, evaluated on each
/// component's preferred device type (first platform device as a
/// fallback). The serving layer's laxity-based admission control compares
/// a request's deadline budget against this: a budget below the floor
/// cannot be met by *any* policy **under the supplied cost model**, so
/// rejecting at arrival never discards work that model deems feasible —
/// deliberately optimistic, never an overestimate. The guarantee is only
/// as faithful as the model: real-path wall-clock deadlines should be
/// admitted with a measured table (`pyschedcl calibrate` →
/// `CalibratedCost`, auto-loaded by `pyschedcl serve --mode real`), not
/// the paper's modeled device times.
pub fn app_solo_estimate(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
) -> f64 {
    partition
        .components
        .iter()
        .map(|c| {
            let dev = platform
                .devices
                .iter()
                .find(|d| d.dtype == c.dev)
                .or_else(|| platform.devices.first());
            match dev {
                Some(d) => c
                    .kernels
                    .iter()
                    .map(|&k| cost.exec_time(&dag.kernels[k], d))
                    .fold(0.0, f64::max),
                None => 0.0,
            }
        })
        .fold(0.0, f64::max)
}

/// A component currently resident (dispatched, unfinished) on a device —
/// the candidate victim set offered to [`Policy::preempt`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResidentTenant {
    pub comp: usize,
    pub device: DeviceId,
}

/// The paper's overridable `select` routine over the event-driven
/// scheduler core: choose a ready component and a device, or `None` to
/// block until an event updates the state.
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// Pick `(component, device)` from the indexed frontier, or `None` to
    /// block. The state is `&mut` because head queries prune lazily
    /// deleted heap entries; `select` must not consume frontier entries
    /// itself — the engine applies the decision via
    /// [`SchedState::on_dispatch`].
    fn select(&mut self, state: &mut SchedState) -> Option<(usize, DeviceId)>;

    /// Command queues this policy sets up on `device`. Dynamic coarse-grained
    /// baselines force a single queue (paper §5 Expts 2–3).
    fn queues_for(&self, device: &Device) -> usize {
        device.num_queues
    }

    /// Cheap capability probe: when false (the default) the engines skip
    /// building the resident-tenant set and never call
    /// [`Policy::preempt`], keeping the blocked-select path allocation-free
    /// for non-preempting policies.
    fn can_preempt(&self) -> bool {
        false
    }

    /// Preemption hook, consulted by the simulator when `select` blocks
    /// with work still on the frontier (only if [`Policy::can_preempt`]):
    /// return the resident component to displace (its unfinished commands
    /// are cancelled at command-queue granularity and it re-enters the
    /// frontier with remaining solo-seconds preserved), or `None` to wait.
    /// Policies must only preempt a *strictly less urgent* victim,
    /// otherwise displacement can ping-pong. Default: never preempt.
    fn preempt(&mut self, _state: &mut SchedState, _resident: &[ResidentTenant]) -> Option<usize> {
        None
    }
}

/// Static fine-grained *clustering* (Expt 1): dispatch the highest-ranked
/// component whose device preference matches an available device — one
/// bucket-head comparison per device type plus the first matching device
/// in available-set order, O(log F).
#[derive(Debug, Default)]
pub struct Clustering;

impl Policy for Clustering {
    fn name(&self) -> &'static str {
        "clustering"
    }

    fn select(&mut self, state: &mut SchedState) -> Option<(usize, DeviceId)> {
        let comp = state.rank_head_placeable()?;
        let dev = state.first_available_of(state.pref(comp))?;
        Some((comp, dev))
    }
}

/// Dynamic *eager* execution (Expt 2, StarPU-inspired): highest-ranked
/// component onto **any** available device, ignoring preferences — the
/// greedy behaviour whose pathology (GEMMs landing on the CPU) the paper
/// dissects in Fig. 13(a). Coarse-grained: one queue per device.
#[derive(Debug, Default)]
pub struct Eager;

impl Policy for Eager {
    fn name(&self) -> &'static str {
        "eager"
    }

    fn select(&mut self, state: &mut SchedState) -> Option<(usize, DeviceId)> {
        let comp = state.rank_head()?;
        let dev = state.available().first().copied()?;
        Some((comp, dev))
    }

    fn queues_for(&self, _device: &Device) -> usize {
        1
    }
}

/// Dynamic *HEFT* (Expt 3): highest-ranked kernel onto the device with the
/// earliest finishing time, using profiled execution times. Willing to wait
/// for a busy-but-faster device (hence GEMMs stay on the GPU, Fig. 13(b)).
/// Coarse-grained: one queue per device.
#[derive(Debug, Default)]
pub struct Heft;

impl Policy for Heft {
    fn name(&self) -> &'static str {
        "heft"
    }

    fn select(&mut self, state: &mut SchedState) -> Option<(usize, DeviceId)> {
        let comp = state.rank_head()?;
        // argmin over ALL devices of EFT = max(now, est_free) + exec.
        let mut best: Option<(DeviceId, f64)> = None;
        for d in &state.platform.devices {
            if d.num_queues == 0 {
                continue;
            }
            let eft = state.est_free[d.id].max(state.now) + state.component_time(comp, d);
            if best.map(|(_, t)| eft < t).unwrap_or(true) {
                best = Some((d.id, eft));
            }
        }
        let (dev, _) = best?;
        // Dispatch only once the EFT-optimal device is actually free;
        // otherwise block (the component keeps its frontier slot).
        if state.is_available(dev) {
            Some((comp, dev))
        } else {
            None
        }
    }

    fn queues_for(&self, _device: &Device) -> usize {
        1
    }
}

/// Load-aware serving policy: like [`Clustering`] it honours device-type
/// preference, but among matching candidates it picks the device carrying
/// the least cross-DAG occupancy (ties broken by earliest `est_free`) — the
/// natural `select` for multi-tenant platforms with several GPUs serving
/// concurrent requests.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Policy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn select(&mut self, state: &mut SchedState) -> Option<(usize, DeviceId)> {
        let comp = state.rank_head_placeable()?;
        let dev = state.least_loaded_available_of(state.pref(comp))?;
        Some((comp, dev))
    }
}

/// Deadline-aware serving policy: earliest-absolute-deadline first among
/// device-type-compatible candidates, laxity tie-break, falling back to
/// bottom-level rank for deadline-free components. When every compatible
/// device is occupied, [`Edf::preempt`] displaces the least urgent resident
/// tenant — but only one *strictly* less urgent than the blocked
/// head-of-line request. Dominance uses the same lexicographic order as
/// `select` (earlier deadline first, then laxity, then priority), so a
/// displaced victim can never be re-selected ahead of the component that
/// displaced it — displacement cannot ping-pong.
///
/// On the indexed state the urgency order is served by the per-type
/// deadline heaps (finite deadlines) and fallback heaps (∞ deadlines):
/// the head is O(T · log F) where T is the number of components tied
/// bitwise at the minimum deadline — the view-based implementation
/// re-derived the whole order in O(F) (plus an O(F) laxity-tie hashmap)
/// per call.
#[derive(Debug, Default)]
pub struct Edf;

impl Policy for Edf {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn select(&mut self, state: &mut SchedState) -> Option<(usize, DeviceId)> {
        // With no urgency metadata anywhere the order degenerates to the
        // frontier's native rank order — the carrier counter makes the
        // probe O(1) (e.g. `--policy edf` without any deadline flags).
        if state.meta_carriers() == 0 {
            let comp = state.rank_head_placeable()?;
            let dev = state.least_loaded_available_of(state.pref(comp))?;
            return Some((comp, dev));
        }
        // Common dispatch path: the urgency-order head is usually
        // placeable.
        let head = state.urgency_head(false)?;
        if let Some(dev) = state.least_loaded_available_of(state.pref(head)) {
            return Some((head, dev));
        }
        // Head unplaceable: the most urgent component among those whose
        // preferred type still has availability — `None` when the frontier
        // is fully blocked. (The view-based policy sorted the entire
        // frontier here.)
        let next = state.urgency_head(true)?;
        let dev = state.least_loaded_available_of(state.pref(next))?;
        Some((next, dev))
    }

    fn can_preempt(&self) -> bool {
        true
    }

    fn preempt(&mut self, state: &mut SchedState, resident: &[ResidentTenant]) -> Option<usize> {
        // Head-of-line blocked request: the most urgent frontier component
        // that actually carries urgency metadata (a finite deadline or a
        // non-default priority) — rank-only work never preempts. Any
        // carrier is strictly more urgent than any non-carrier in the
        // shared order, so with carriers present the global urgency head
        // *is* the most urgent carrier.
        if state.meta_carriers() == 0 {
            return None;
        }
        let urgent = state.urgency_head(false)?;
        let want = state.pref(urgent);
        // Eligibility is strict dominance in the full select order (the
        // no-ping-pong invariant) AND a genuine SLO gain — a strictly
        // earlier deadline or strictly higher priority. Laxity-only
        // dominance (equal deadline, equal priority) is excluded: that is
        // typically a sibling component of the same request, and paying a
        // transfer re-stage to reorder siblings delays the very deadline
        // being optimized. Least urgent victim = maximum in the shared
        // urgency order (last of equals, matching the view-based max_by).
        let mut victim: Option<usize> = None;
        for r in resident {
            if state.platform.device(r.device).dtype != want {
                continue;
            }
            let dominated = state.urgency_cmp(urgent, r.comp).is_lt()
                && (state.deadline[urgent] < state.deadline[r.comp]
                    || state.priority[urgent] > state.priority[r.comp]);
            if !dominated {
                continue;
            }
            victim = match victim {
                Some(v) if state.urgency_cmp(r.comp, v).is_lt() => Some(v),
                _ => Some(r.comp),
            };
        }
        victim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::platform::DeviceType;
    use crate::transformer::{cluster_by_head, transformer_dag};

    /// Build a state with `frontier` fed in order (FIFO seq order) and
    /// only `available` devices left in the available set.
    #[allow(clippy::too_many_arguments)]
    fn state_with<'a>(
        dag: &'a Dag,
        part: &'a Partition,
        platform: &'a Platform,
        frontier: &[usize],
        available: &[DeviceId],
        est_free: &[f64],
        device_load: &[f64],
        deadline: &[f64],
        priority: &[u32],
    ) -> SchedState<'a> {
        let mut st = SchedState::new(
            dag,
            part,
            platform,
            &PaperCost,
            1,
            deadline.to_vec(),
            priority.to_vec(),
        )
        .unwrap();
        for &c in frontier {
            st.on_ready(c);
        }
        for d in 0..platform.devices.len() {
            if !available.contains(&d) {
                st.mark_unavailable(d);
            }
        }
        st.est_free.copy_from_slice(est_free);
        st.device_load.copy_from_slice(device_load);
        st
    }

    /// Neutral serving metadata: no deadlines, default priority.
    fn no_meta(ncomp: usize) -> (Vec<f64>, Vec<u32>) {
        (vec![f64::INFINITY; ncomp], vec![0u32; ncomp])
    }

    #[test]
    fn app_solo_estimate_is_a_makespan_lower_bound() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0); // both components GPU-pref
        let platform = Platform::paper_testbed(3, 1);
        let est = app_solo_estimate(&dag, &part, &platform, &PaperCost);
        assert!(est > 0.0 && est.is_finite());
        // The floor is the longest single kernel on the preferred device —
        // never the per-component sum (queues overlap independent kernels,
        // so the sum would overestimate and admission would reject feasible
        // requests).
        let gpu = platform.device(0);
        let longest = dag
            .kernels
            .iter()
            .map(|k| PaperCost.exec_time(k, gpu))
            .fold(0.0f64, f64::max);
        let sum: f64 = part.components[0]
            .kernels
            .iter()
            .map(|&k| PaperCost.exec_time(&dag.kernels[k], gpu))
            .sum();
        assert!((est - longest).abs() < 1e-12, "est {est} vs longest {longest}");
        assert!(est < sum, "floor {est} must undercut the serial sum {sum}");
    }

    #[test]
    fn clustering_respects_device_preference() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 1); // head 0 on CPU
        let platform = Platform::paper_testbed(2, 1);
        let frontier = [0usize, 1];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        let (dl, pr) = no_meta(2);
        // Only the CPU (device 1) available: must pick comp 0 (cpu-pref).
        let mut v = state_with(&dag, &part, &platform, &frontier, &[1], &est, &load, &dl, &pr);
        assert_eq!(Clustering.select(&mut v), Some((0, 1)));
        // Only the GPU available: must skip comp 0 and pick comp 1.
        let mut v = state_with(&dag, &part, &platform, &frontier, &[0], &est, &load, &dl, &pr);
        assert_eq!(Clustering.select(&mut v), Some((1, 0)));
        // Nothing available: block.
        let mut v = state_with(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Clustering.select(&mut v), None);
    }

    #[test]
    fn eager_ignores_preference() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0); // all GPU-pref
        let platform = Platform::paper_testbed(1, 1);
        let frontier = [0usize, 1];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        let (dl, pr) = no_meta(2);
        // CPU-only availability: eager still dispatches there.
        let mut v = state_with(&dag, &part, &platform, &frontier, &[1], &est, &load, &dl, &pr);
        assert_eq!(Eager.select(&mut v), Some((0, 1)));
        assert_eq!(Eager.queues_for(platform.device(0)), 1);
    }

    #[test]
    fn heft_waits_for_faster_busy_device() {
        let (dag, ios) = transformer_dag(1, 256, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(1, 1);
        let frontier = [0usize];
        let load = [0.0, 0.0];
        let (dl, pr) = no_meta(1);
        // GPU busy for a short while; CPU idle. GEMM component is far
        // faster on the GPU, so HEFT blocks rather than take the CPU.
        let est = [0.005, 0.0];
        let mut v = state_with(&dag, &part, &platform, &frontier, &[1], &est, &load, &dl, &pr);
        assert_eq!(Heft.select(&mut v), None);
        // Once the GPU frees, it dispatches there.
        let est = [0.0, 0.0];
        let mut v =
            state_with(&dag, &part, &platform, &frontier, &[0, 1], &est, &load, &dl, &pr);
        assert_eq!(Heft.select(&mut v), Some((0, 0)));
    }

    #[test]
    fn heft_takes_cpu_when_gpu_backlog_huge() {
        let (dag, ios) = transformer_dag(1, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(1, 1);
        let frontier = [0usize];
        let est = [100.0, 0.0]; // GPU booked out for 100 s
        let load = [0.0, 0.0];
        let (dl, pr) = no_meta(1);
        let mut v = state_with(&dag, &part, &platform, &frontier, &[1], &est, &load, &dl, &pr);
        assert_eq!(Heft.select(&mut v), Some((0, 1)));
    }

    #[test]
    fn least_loaded_spreads_across_matching_devices() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0); // both components GPU-pref
        let platform = Platform::scaled(2, 1, 3, 1); // two GPUs + one CPU
        let frontier = [0usize, 1];
        let est = [0.0, 0.0, 0.0];
        let (dl, pr) = no_meta(2);
        // GPU 0 is half loaded, GPU 1 idle: pick GPU 1.
        let load = [0.5, 0.0, 0.0];
        let mut v = state_with(
            &dag, &part, &platform, &frontier, &[0, 1, 2], &est, &load, &dl, &pr,
        );
        assert_eq!(LeastLoaded.select(&mut v), Some((0, 1)));
        // Only the CPU available: a GPU-pref component blocks (preference
        // honoured, unlike eager).
        let mut v = state_with(&dag, &part, &platform, &frontier, &[2], &est, &load, &dl, &pr);
        assert_eq!(LeastLoaded.select(&mut v), None);
    }

    #[test]
    fn edf_picks_earliest_absolute_deadline_over_rank() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0); // both GPU-pref
        let platform = Platform::paper_testbed(3, 1);
        // Frontier in rank order prefers comp 0; comp 1's deadline is
        // tighter, so EDF must invert the order.
        let frontier = [0usize, 1];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        let dl = [0.5, 0.2];
        let pr = [0u32, 0];
        let mut v = state_with(&dag, &part, &platform, &frontier, &[0], &est, &load, &dl, &pr);
        assert_eq!(Edf.select(&mut v), Some((1, 0)));
        // No deadlines at all: EDF degrades to the rank-order frontier.
        let (dl, pr) = no_meta(2);
        let mut v = state_with(&dag, &part, &platform, &frontier, &[0], &est, &load, &dl, &pr);
        assert_eq!(Edf.select(&mut v), Some((0, 0)));
    }

    #[test]
    fn edf_breaks_deadline_ties_by_laxity() {
        // h_cpu = 1: head 0 prefers the CPU (slow ⇒ little slack), head 1
        // the GPU (fast ⇒ plenty). Equal absolute deadlines, so laxity is
        // the tie-break and the CPU-bound component must go first, even
        // though the frontier lists head 1 ahead of it.
        let (dag, ios) = transformer_dag(2, 256, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 1);
        let platform = Platform::paper_testbed(3, 1);
        let frontier = [1usize, 0];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        let dl = [0.4, 0.4];
        let pr = [0u32, 0];
        let mut v = state_with(
            &dag, &part, &platform, &frontier, &[0, 1], &est, &load, &dl, &pr,
        );
        assert!(v.laxity(0) < v.laxity(1), "CPU comp should have less slack");
        assert_eq!(Edf.select(&mut v).map(|(c, _)| c), Some(0));
        // Equal deadline + equal laxity (identical comps): priority breaks
        // the tie.
        let part_gpu = cluster_by_head(&dag, &ios, 0);
        let pr = [0u32, 3];
        let mut v = state_with(
            &dag, &part_gpu, &platform, &frontier, &[0, 1], &est, &load, &dl, &pr,
        );
        assert_eq!(Edf.select(&mut v).map(|(c, _)| c), Some(1));
    }

    #[test]
    fn edf_preempts_only_strictly_less_urgent_residents() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 1);
        let frontier = [1usize]; // comp 1 blocked (GPU full)
        let est = [0.0, 0.0];
        let load = [1.0, 0.0];
        let resident = [ResidentTenant { comp: 0, device: 0 }];
        // Urgent comp 1 (tight deadline) vs resident comp 0 (no deadline):
        // displace comp 0.
        let dl = [f64::INFINITY, 0.1];
        let pr = [0u32, 0];
        let mut v = state_with(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&mut v, &resident), Some(0));
        // Resident is *more* urgent (earlier deadline): no preemption.
        let dl = [0.05, 0.1];
        let mut v = state_with(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&mut v, &resident), None);
        // Equal urgency: no preemption (strictness prevents ping-pong).
        let dl = [0.1, 0.1];
        let mut v = state_with(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&mut v, &resident), None);
        // Higher priority displaces even without a deadline edge.
        let dl = [f64::INFINITY, f64::INFINITY];
        let pr = [0u32, 2];
        let mut v = state_with(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&mut v, &resident), Some(0));
        // Rank-only frontier (no deadline, no priority): never preempts.
        let pr = [0u32, 0];
        let mut v = state_with(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&mut v, &resident), None);
    }
}
