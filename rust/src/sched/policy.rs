//! The pluggable `select` routine of Algorithm 1 and the three policies the
//! paper evaluates.

use crate::cost::CostModel;
use crate::graph::{Dag, Partition};
use crate::platform::{Device, DeviceId, Platform};

/// Read-only scheduler state offered to `select` (Algorithm 1 line 5):
/// the frontier `F` (rank-sorted, descending), the available-device set `A`,
/// and auxiliary estimates for EFT-style policies.
pub struct SchedView<'a> {
    pub now: f64,
    /// Ready component ids, sorted by bottom-level rank, best first.
    pub frontier: &'a [usize],
    /// Available (idle) devices.
    pub available: &'a [DeviceId],
    pub platform: &'a Platform,
    pub partition: &'a Partition,
    pub dag: &'a Dag,
    /// Estimated time each device becomes free (≤ now when idle).
    pub est_free: &'a [f64],
    /// Cross-DAG busyness signal per device: 0 when idle, growing as the
    /// device takes on work. The simulator reports Σ occupancy of running
    /// kernels (may exceed 1.0), served from an incrementally-invalidated
    /// cache — policies must treat it as read-only state, never as a value
    /// they can perturb; the real executor reports the
    /// resident-component fraction (tenants/tenancy, capped at 1.0).
    /// Policies should compare devices *relatively* (less vs more loaded),
    /// not against absolute thresholds. Under multi-tenant serving several
    /// components — possibly from different requests — share one device, so
    /// `available` alone no longer says how loaded a device is.
    pub device_load: &'a [f64],
    /// Absolute deadline per component, seconds since the serving epoch
    /// (`f64::INFINITY` when the request carries none). Threaded from
    /// `ServeRequest.deadline` through the merged application so
    /// deadline-aware policies ([`Edf`]) can order the frontier by urgency.
    pub deadline: &'a [f64],
    /// Request priority per component (larger = more urgent; 0 default).
    pub priority: &'a [u32],
    pub cost: &'a dyn CostModel,
}

impl<'a> SchedView<'a> {
    /// Solo execution-time estimate of an entire component on a device.
    pub fn component_time(&self, comp: usize, dev: &Device) -> f64 {
        self.partition.components[comp]
            .kernels
            .iter()
            .map(|&k| self.cost.exec_time(&self.dag.kernels[k], dev))
            .sum()
    }

    /// Laxity of `comp`: slack between its absolute deadline and its
    /// estimated completion were it dispatched *now* on a device of its
    /// preferred type (+∞ for deadline-free components). Negative laxity
    /// means the deadline is already unmeetable under the solo estimate.
    pub fn laxity(&self, comp: usize) -> f64 {
        if self.deadline[comp].is_infinite() {
            return f64::INFINITY;
        }
        let want = self.partition.components[comp].dev;
        let dev = self
            .platform
            .devices
            .iter()
            .find(|d| d.dtype == want)
            .or_else(|| self.platform.devices.first());
        match dev {
            Some(d) => self.deadline[comp] - self.now - self.component_time(comp, d),
            None => f64::INFINITY,
        }
    }
}

/// Optimistic solo-seconds estimate of one whole application — a true
/// **lower bound** on its makespan. Components are independent (they could
/// run fully in parallel) and a component's kernels overlap across the
/// device's command queues, so the only schedule-independent floor is the
/// single longest kernel anywhere in the application, evaluated on each
/// component's preferred device type (first platform device as a
/// fallback). The serving layer's laxity-based admission control compares
/// a request's deadline budget against this: a budget below the floor
/// cannot be met by *any* policy **under the supplied cost model**, so
/// rejecting at arrival never discards work that model deems feasible —
/// deliberately optimistic, never an overestimate. The guarantee is only
/// as faithful as the model: real-path wall-clock deadlines should be
/// admitted with a measured table (`pyschedcl calibrate` →
/// `CalibratedCost`, auto-loaded by `pyschedcl serve --mode real`), not
/// the paper's modeled device times.
pub fn app_solo_estimate(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
) -> f64 {
    partition
        .components
        .iter()
        .map(|c| {
            let dev = platform
                .devices
                .iter()
                .find(|d| d.dtype == c.dev)
                .or_else(|| platform.devices.first());
            match dev {
                Some(d) => c
                    .kernels
                    .iter()
                    .map(|&k| cost.exec_time(&dag.kernels[k], d))
                    .fold(0.0, f64::max),
                None => 0.0,
            }
        })
        .fold(0.0, f64::max)
}

/// A component currently resident (dispatched, unfinished) on a device —
/// the candidate victim set offered to [`Policy::preempt`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResidentTenant {
    pub comp: usize,
    pub device: DeviceId,
}

/// The paper's overridable `select` routine: choose a ready component and a
/// device, or `None` to block until a callback updates `F`/`A`.
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)>;

    /// Command queues this policy sets up on `device`. Dynamic coarse-grained
    /// baselines force a single queue (paper §5 Expts 2–3).
    fn queues_for(&self, device: &Device) -> usize {
        device.num_queues
    }

    /// Cheap capability probe: when false (the default) the simulator
    /// skips building the resident-tenant set and never calls
    /// [`Policy::preempt`], keeping the blocked-select path allocation-free
    /// for non-preempting policies.
    fn can_preempt(&self) -> bool {
        false
    }

    /// Preemption hook, consulted by the simulator when `select` blocks
    /// with work still on the frontier (only if [`Policy::can_preempt`]):
    /// return the resident component to displace (its unfinished commands
    /// are cancelled at command-queue granularity and it re-enters the
    /// frontier with remaining solo-seconds preserved), or `None` to wait.
    /// Policies must only preempt a *strictly less urgent* victim,
    /// otherwise displacement can ping-pong. Default: never preempt.
    fn preempt(&mut self, _view: &SchedView, _resident: &[ResidentTenant]) -> Option<usize> {
        None
    }
}

/// Static fine-grained *clustering* (Expt 1): dispatch the highest-ranked
/// component whose device preference matches an available device.
#[derive(Debug, Default)]
pub struct Clustering;

impl Policy for Clustering {
    fn name(&self) -> &'static str {
        "clustering"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        for &comp in view.frontier {
            let want = view.partition.components[comp].dev;
            if let Some(&dev) = view
                .available
                .iter()
                .find(|&&d| view.platform.device(d).dtype == want)
            {
                return Some((comp, dev));
            }
        }
        None
    }
}

/// Dynamic *eager* execution (Expt 2, StarPU-inspired): highest-ranked
/// component onto **any** available device, ignoring preferences — the
/// greedy behaviour whose pathology (GEMMs landing on the CPU) the paper
/// dissects in Fig. 13(a). Coarse-grained: one queue per device.
#[derive(Debug, Default)]
pub struct Eager;

impl Policy for Eager {
    fn name(&self) -> &'static str {
        "eager"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        let comp = *view.frontier.first()?;
        let dev = *view.available.first()?;
        Some((comp, dev))
    }

    fn queues_for(&self, _device: &Device) -> usize {
        1
    }
}

/// Dynamic *HEFT* (Expt 3): highest-ranked kernel onto the device with the
/// earliest finishing time, using profiled execution times. Willing to wait
/// for a busy-but-faster device (hence GEMMs stay on the GPU, Fig. 13(b)).
/// Coarse-grained: one queue per device.
#[derive(Debug, Default)]
pub struct Heft;

impl Policy for Heft {
    fn name(&self) -> &'static str {
        "heft"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        let comp = *view.frontier.first()?;
        // argmin over ALL devices of EFT = max(now, est_free) + exec.
        let mut best: Option<(DeviceId, f64)> = None;
        for d in &view.platform.devices {
            if d.num_queues == 0 {
                continue;
            }
            let eft = view.est_free[d.id].max(view.now) + view.component_time(comp, d);
            if best.map(|(_, t)| eft < t).unwrap_or(true) {
                best = Some((d.id, eft));
            }
        }
        let (dev, _) = best?;
        // Dispatch only once the EFT-optimal device is actually free;
        // otherwise block (the component keeps its frontier slot).
        if view.available.contains(&dev) {
            Some((comp, dev))
        } else {
            None
        }
    }

    fn queues_for(&self, _device: &Device) -> usize {
        1
    }
}

/// Load-aware serving policy: like [`Clustering`] it honours device-type
/// preference, but among matching candidates it picks the device carrying
/// the least cross-DAG occupancy (ties broken by earliest `est_free`) — the
/// natural `select` for multi-tenant platforms with several GPUs serving
/// concurrent requests.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Policy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        for &comp in view.frontier {
            let want = view.partition.components[comp].dev;
            let best = view
                .available
                .iter()
                .copied()
                .filter(|&d| view.platform.device(d).dtype == want)
                .min_by(|&a, &b| {
                    view.device_load[a]
                        .total_cmp(&view.device_load[b])
                        .then_with(|| view.est_free[a].total_cmp(&view.est_free[b]))
                });
            if let Some(dev) = best {
                return Some((comp, dev));
            }
        }
        None
    }
}

/// Deadline-aware serving policy: earliest-absolute-deadline first among
/// device-type-compatible candidates, laxity tie-break, falling back to
/// bottom-level rank for deadline-free components. When every compatible
/// device is occupied, [`Edf::preempt`] displaces the least urgent resident
/// tenant — but only one *strictly* less urgent than the blocked
/// head-of-line request. Dominance uses the same lexicographic order as
/// `select` (earlier deadline first, then laxity, then priority), so a
/// displaced victim can never be re-selected ahead of the component that
/// displaced it — displacement cannot ping-pong.
#[derive(Debug, Default)]
pub struct Edf;

impl Edf {
    /// The one urgency comparator behind `select` ordering, the blocked
    /// head-of-line scan, AND preemption dominance: deadline ascending,
    /// laxity ascending on exact deadline ties, then priority descending.
    /// Using a single total order everywhere is what makes the no-ping-pong
    /// argument sound — a victim re-entering the frontier can never be
    /// re-selected ahead of the component that displaced it. `la`/`lb` are
    /// the candidates' laxities, passed in so callers control when the
    /// cost-model sum behind [`SchedView::laxity`] actually runs.
    fn cmp_with(view: &SchedView, a: usize, la: f64, b: usize, lb: f64) -> std::cmp::Ordering {
        view.deadline[a]
            .total_cmp(&view.deadline[b])
            .then_with(|| la.total_cmp(&lb))
            .then_with(|| view.priority[b].cmp(&view.priority[a]))
    }

    /// Laxity per frontier candidate, computed only where the comparator
    /// can reach it — on finite deadlines shared by another candidate. The
    /// placeholder (∞) for untied candidates is never consulted, because
    /// a distinct deadline decides the comparison first. The map is
    /// pre-sized to the frontier (this runs once per `select`; growth
    /// rehashes were measurable on large serving frontiers).
    fn tied_laxities(view: &SchedView) -> Vec<(usize, f64)> {
        let mut counts: std::collections::HashMap<u64, u32> =
            std::collections::HashMap::with_capacity(view.frontier.len());
        for &c in view.frontier {
            if view.deadline[c].is_finite() {
                *counts.entry(view.deadline[c].to_bits()).or_insert(0) += 1;
            }
        }
        view.frontier
            .iter()
            .map(|&c| {
                let d = view.deadline[c];
                let tied = d.is_finite() && counts.get(&d.to_bits()).is_some_and(|&n| n > 1);
                (c, if tied { view.laxity(c) } else { f64::INFINITY })
            })
            .collect()
    }

    /// Lazy pairwise form of [`Edf::cmp_with`]: laxity is only computed on
    /// exact deadline ties (`then_with` short-circuits). Pairwise identical
    /// to `cmp_with` over [`Edf::tied_laxities`] — tied deadlines get real
    /// laxities in both, untied ones never reach the laxity term.
    fn urgency_cmp(view: &SchedView, a: usize, b: usize) -> std::cmp::Ordering {
        view.deadline[a]
            .total_cmp(&view.deadline[b])
            .then_with(|| view.laxity(a).total_cmp(&view.laxity(b)))
            .then_with(|| view.priority[b].cmp(&view.priority[a]))
    }

    /// Strict urgency dominance in the select order: true iff `a` is
    /// strictly more urgent than `b`.
    fn more_urgent(view: &SchedView, a: usize, b: usize) -> bool {
        Edf::urgency_cmp(view, a, b).is_lt()
    }

    /// Least-loaded available device matching `comp`'s type preference.
    fn best_device(view: &SchedView, comp: usize) -> Option<DeviceId> {
        let want = view.partition.components[comp].dev;
        view.available
            .iter()
            .copied()
            .filter(|&d| view.platform.device(d).dtype == want)
            .min_by(|&a, &b| {
                view.device_load[a]
                    .total_cmp(&view.device_load[b])
                    .then_with(|| view.est_free[a].total_cmp(&view.est_free[b]))
            })
    }

    /// Head-of-line blocked candidate: the urgency-order minimum restricted
    /// to components carrying urgency metadata — one O(F) pass instead of a
    /// full sort per blocked round.
    fn most_urgent_candidate(view: &SchedView) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (c, lax) in Edf::tied_laxities(view) {
            if !(view.deadline[c].is_finite() || view.priority[c] > 0) {
                continue;
            }
            let better = match best {
                None => true,
                Some((b, bl)) => Edf::cmp_with(view, c, lax, b, bl).is_lt(),
            };
            if better {
                best = Some((c, lax));
            }
        }
        best.map(|(c, _)| c)
    }
}

impl Policy for Edf {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        // With no urgency metadata anywhere the order degenerates to the
        // frontier's native rank order — skip the laxity/sort machinery
        // entirely (e.g. `--policy edf` without any deadline flags).
        if view
            .frontier
            .iter()
            .all(|&c| view.deadline[c].is_infinite() && view.priority[c] == 0)
        {
            return view
                .frontier
                .iter()
                .find_map(|&c| Edf::best_device(view, c).map(|d| (c, d)));
        }
        // Common dispatch path, sort-free: the urgency-order head is
        // usually placeable. min_by keeps the *first* of equally-minimum
        // elements — the same candidate a stable sort would put at the
        // head.
        let cands = Edf::tied_laxities(view);
        let head = cands
            .iter()
            .copied()
            .min_by(|&(a, la), &(b, lb)| Edf::cmp_with(view, a, la, b, lb))
            .map(|(c, _)| c)?;
        if let Some(dev) = Edf::best_device(view, head) {
            return Some((head, dev));
        }
        // Head unplaceable. Fully-blocked rounds (the other common case)
        // exit without sorting; the full sort only runs when some *other*
        // candidate can be placed.
        if !view
            .frontier
            .iter()
            .any(|&c| Edf::best_device(view, c).is_some())
        {
            return None;
        }
        let mut order = cands;
        order.sort_by(|&(a, la), &(b, lb)| Edf::cmp_with(view, a, la, b, lb));
        for (comp, _) in order {
            if comp == head {
                continue;
            }
            if let Some(dev) = Edf::best_device(view, comp) {
                return Some((comp, dev));
            }
        }
        None
    }

    fn can_preempt(&self) -> bool {
        true
    }

    fn preempt(&mut self, view: &SchedView, resident: &[ResidentTenant]) -> Option<usize> {
        // Head-of-line blocked request: the most urgent frontier component
        // that actually carries urgency metadata (a finite deadline or a
        // non-default priority) — rank-only work never preempts. Because
        // the candidate order and `more_urgent` agree, this is the select
        // order's head whenever any candidate carries metadata, and the
        // post-displacement `select` is guaranteed to place it.
        let urgent = Edf::most_urgent_candidate(view)?;
        let want = view.partition.components[urgent].dev;
        // Eligibility is strict dominance in the full select order (the
        // no-ping-pong invariant) AND a genuine SLO gain — a strictly
        // earlier deadline or strictly higher priority. Laxity-only
        // dominance (equal deadline, equal priority) is excluded: that is
        // typically a sibling component of the same request, and paying a
        // transfer re-stage to reorder siblings delays the very deadline
        // being optimized.
        resident
            .iter()
            .filter(|r| view.platform.device(r.device).dtype == want)
            .filter(|r| {
                Edf::more_urgent(view, urgent, r.comp)
                    && (view.deadline[urgent] < view.deadline[r.comp]
                        || view.priority[urgent] > view.priority[r.comp])
            })
            // Least urgent victim = maximum in the shared urgency order.
            .max_by(|a, b| Edf::urgency_cmp(view, a.comp, b.comp))
            .map(|r| r.comp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::platform::DeviceType;
    use crate::transformer::{cluster_by_head, transformer_dag};

    /// Neutral serving metadata: no deadlines, default priority.
    fn no_meta(ncomp: usize) -> (Vec<f64>, Vec<u32>) {
        (vec![f64::INFINITY; ncomp], vec![0u32; ncomp])
    }

    #[allow(clippy::too_many_arguments)]
    fn view_meta<'a>(
        dag: &'a Dag,
        part: &'a Partition,
        platform: &'a Platform,
        frontier: &'a [usize],
        available: &'a [DeviceId],
        est_free: &'a [f64],
        device_load: &'a [f64],
        deadline: &'a [f64],
        priority: &'a [u32],
    ) -> SchedView<'a> {
        SchedView {
            now: 0.0,
            frontier,
            available,
            platform,
            partition: part,
            dag,
            est_free,
            device_load,
            deadline,
            priority,
            cost: &PaperCost,
        }
    }

    #[test]
    fn app_solo_estimate_is_a_makespan_lower_bound() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0); // both components GPU-pref
        let platform = Platform::paper_testbed(3, 1);
        let est = app_solo_estimate(&dag, &part, &platform, &PaperCost);
        assert!(est > 0.0 && est.is_finite());
        // The floor is the longest single kernel on the preferred device —
        // never the per-component sum (queues overlap independent kernels,
        // so the sum would overestimate and admission would reject feasible
        // requests).
        let gpu = platform.device(0);
        let longest = dag
            .kernels
            .iter()
            .map(|k| PaperCost.exec_time(k, gpu))
            .fold(0.0f64, f64::max);
        let sum: f64 = part.components[0]
            .kernels
            .iter()
            .map(|&k| PaperCost.exec_time(&dag.kernels[k], gpu))
            .sum();
        assert!((est - longest).abs() < 1e-12, "est {est} vs longest {longest}");
        assert!(est < sum, "floor {est} must undercut the serial sum {sum}");
    }

    #[test]
    fn clustering_respects_device_preference() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 1); // head 0 on CPU
        let platform = Platform::paper_testbed(2, 1);
        let frontier = [0usize, 1];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        let (dl, pr) = no_meta(2);
        // Only the CPU (device 1) available: must pick comp 0 (cpu-pref).
        let v = view_meta(&dag, &part, &platform, &frontier, &[1], &est, &load, &dl, &pr);
        assert_eq!(Clustering.select(&v), Some((0, 1)));
        // Only the GPU available: must skip comp 0 and pick comp 1.
        let v = view_meta(&dag, &part, &platform, &frontier, &[0], &est, &load, &dl, &pr);
        assert_eq!(Clustering.select(&v), Some((1, 0)));
        // Nothing available: block.
        let v = view_meta(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Clustering.select(&v), None);
    }

    #[test]
    fn eager_ignores_preference() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0); // all GPU-pref
        let platform = Platform::paper_testbed(1, 1);
        let frontier = [0usize, 1];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        let (dl, pr) = no_meta(2);
        // CPU-only availability: eager still dispatches there.
        let v = view_meta(&dag, &part, &platform, &frontier, &[1], &est, &load, &dl, &pr);
        assert_eq!(Eager.select(&v), Some((0, 1)));
        assert_eq!(Eager.queues_for(platform.device(0)), 1);
    }

    #[test]
    fn heft_waits_for_faster_busy_device() {
        let (dag, ios) = transformer_dag(1, 256, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(1, 1);
        let frontier = [0usize];
        let load = [0.0, 0.0];
        let (dl, pr) = no_meta(1);
        // GPU busy for a short while; CPU idle. GEMM component is far
        // faster on the GPU, so HEFT blocks rather than take the CPU.
        let est = [0.005, 0.0];
        let v = view_meta(&dag, &part, &platform, &frontier, &[1], &est, &load, &dl, &pr);
        assert_eq!(Heft.select(&v), None);
        // Once the GPU frees, it dispatches there.
        let est = [0.0, 0.0];
        let v = view_meta(&dag, &part, &platform, &frontier, &[0, 1], &est, &load, &dl, &pr);
        assert_eq!(Heft.select(&v), Some((0, 0)));
    }

    #[test]
    fn heft_takes_cpu_when_gpu_backlog_huge() {
        let (dag, ios) = transformer_dag(1, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(1, 1);
        let frontier = [0usize];
        let est = [100.0, 0.0]; // GPU booked out for 100 s
        let load = [0.0, 0.0];
        let (dl, pr) = no_meta(1);
        let v = view_meta(&dag, &part, &platform, &frontier, &[1], &est, &load, &dl, &pr);
        assert_eq!(Heft.select(&v), Some((0, 1)));
    }

    #[test]
    fn least_loaded_spreads_across_matching_devices() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0); // both components GPU-pref
        let platform = Platform::scaled(2, 1, 3, 1); // two GPUs + one CPU
        let frontier = [0usize, 1];
        let est = [0.0, 0.0, 0.0];
        let (dl, pr) = no_meta(2);
        // GPU 0 is half loaded, GPU 1 idle: pick GPU 1.
        let load = [0.5, 0.0, 0.0];
        let v = view_meta(&dag, &part, &platform, &frontier, &[0, 1, 2], &est, &load, &dl, &pr);
        assert_eq!(LeastLoaded.select(&v), Some((0, 1)));
        // Only the CPU available: a GPU-pref component blocks (preference
        // honoured, unlike eager).
        let v = view_meta(&dag, &part, &platform, &frontier, &[2], &est, &load, &dl, &pr);
        assert_eq!(LeastLoaded.select(&v), None);
    }

    #[test]
    fn edf_picks_earliest_absolute_deadline_over_rank() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0); // both GPU-pref
        let platform = Platform::paper_testbed(3, 1);
        // Frontier in rank order prefers comp 0; comp 1's deadline is
        // tighter, so EDF must invert the order.
        let frontier = [0usize, 1];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        let dl = [0.5, 0.2];
        let pr = [0u32, 0];
        let v = view_meta(&dag, &part, &platform, &frontier, &[0], &est, &load, &dl, &pr);
        assert_eq!(Edf.select(&v), Some((1, 0)));
        // No deadlines at all: EDF degrades to the rank-order frontier.
        let (dl, pr) = no_meta(2);
        let v = view_meta(&dag, &part, &platform, &frontier, &[0], &est, &load, &dl, &pr);
        assert_eq!(Edf.select(&v), Some((0, 0)));
    }

    #[test]
    fn edf_breaks_deadline_ties_by_laxity() {
        // h_cpu = 1: head 0 prefers the CPU (slow ⇒ little slack), head 1
        // the GPU (fast ⇒ plenty). Equal absolute deadlines, so laxity is
        // the tie-break and the CPU-bound component must go first, even
        // though the rank-ordered frontier lists head 1 ahead of it.
        let (dag, ios) = transformer_dag(2, 256, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 1);
        let platform = Platform::paper_testbed(3, 1);
        let frontier = [1usize, 0];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        let dl = [0.4, 0.4];
        let pr = [0u32, 0];
        let v = view_meta(&dag, &part, &platform, &frontier, &[0, 1], &est, &load, &dl, &pr);
        assert!(v.laxity(0) < v.laxity(1), "CPU comp should have less slack");
        assert_eq!(Edf.select(&v).map(|(c, _)| c), Some(0));
        // Equal deadline + equal laxity (identical comps): priority breaks
        // the tie.
        let part_gpu = cluster_by_head(&dag, &ios, 0);
        let pr = [0u32, 3];
        let v = view_meta(&dag, &part_gpu, &platform, &frontier, &[0, 1], &est, &load, &dl, &pr);
        assert_eq!(Edf.select(&v).map(|(c, _)| c), Some(1));
    }

    #[test]
    fn edf_preempts_only_strictly_less_urgent_residents() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 1);
        let frontier = [1usize]; // comp 1 blocked (GPU full)
        let est = [0.0, 0.0];
        let load = [1.0, 0.0];
        let resident = [ResidentTenant { comp: 0, device: 0 }];
        // Urgent comp 1 (tight deadline) vs resident comp 0 (no deadline):
        // displace comp 0.
        let dl = [f64::INFINITY, 0.1];
        let pr = [0u32, 0];
        let v = view_meta(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&v, &resident), Some(0));
        // Resident is *more* urgent (earlier deadline): no preemption.
        let dl = [0.05, 0.1];
        let v = view_meta(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&v, &resident), None);
        // Equal urgency: no preemption (strictness prevents ping-pong).
        let dl = [0.1, 0.1];
        let v = view_meta(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&v, &resident), None);
        // Higher priority displaces even without a deadline edge.
        let dl = [f64::INFINITY, f64::INFINITY];
        let pr = [0u32, 2];
        let v = view_meta(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&v, &resident), Some(0));
        // Rank-only frontier (no deadline, no priority): never preempts.
        let pr = [0u32, 0];
        let v = view_meta(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&v, &resident), None);
    }
}
