//! The pluggable `select` routine of Algorithm 1 and the three policies the
//! paper evaluates.

use crate::cost::CostModel;
use crate::graph::{Dag, Partition};
use crate::platform::{Device, DeviceId, Platform};

/// Read-only scheduler state offered to `select` (Algorithm 1 line 5):
/// the frontier `F` (rank-sorted, descending), the available-device set `A`,
/// and auxiliary estimates for EFT-style policies.
pub struct SchedView<'a> {
    pub now: f64,
    /// Ready component ids, sorted by bottom-level rank, best first.
    pub frontier: &'a [usize],
    /// Available (idle) devices.
    pub available: &'a [DeviceId],
    pub platform: &'a Platform,
    pub partition: &'a Partition,
    pub dag: &'a Dag,
    /// Estimated time each device becomes free (≤ now when idle).
    pub est_free: &'a [f64],
    /// Cross-DAG busyness signal per device: 0 when idle, growing as the
    /// device takes on work. The simulator reports Σ occupancy of running
    /// kernels (may exceed 1.0); the real executor reports the
    /// resident-component fraction (tenants/tenancy, capped at 1.0).
    /// Policies should compare devices *relatively* (less vs more loaded),
    /// not against absolute thresholds. Under multi-tenant serving several
    /// components — possibly from different requests — share one device, so
    /// `available` alone no longer says how loaded a device is.
    pub device_load: &'a [f64],
    pub cost: &'a dyn CostModel,
}

impl<'a> SchedView<'a> {
    /// Solo execution-time estimate of an entire component on a device.
    pub fn component_time(&self, comp: usize, dev: &Device) -> f64 {
        self.partition.components[comp]
            .kernels
            .iter()
            .map(|&k| self.cost.exec_time(&self.dag.kernels[k], dev))
            .sum()
    }
}

/// The paper's overridable `select` routine: choose a ready component and a
/// device, or `None` to block until a callback updates `F`/`A`.
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)>;

    /// Command queues this policy sets up on `device`. Dynamic coarse-grained
    /// baselines force a single queue (paper §5 Expts 2–3).
    fn queues_for(&self, device: &Device) -> usize {
        device.num_queues
    }
}

/// Static fine-grained *clustering* (Expt 1): dispatch the highest-ranked
/// component whose device preference matches an available device.
#[derive(Debug, Default)]
pub struct Clustering;

impl Policy for Clustering {
    fn name(&self) -> &'static str {
        "clustering"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        for &comp in view.frontier {
            let want = view.partition.components[comp].dev;
            if let Some(&dev) = view
                .available
                .iter()
                .find(|&&d| view.platform.device(d).dtype == want)
            {
                return Some((comp, dev));
            }
        }
        None
    }
}

/// Dynamic *eager* execution (Expt 2, StarPU-inspired): highest-ranked
/// component onto **any** available device, ignoring preferences — the
/// greedy behaviour whose pathology (GEMMs landing on the CPU) the paper
/// dissects in Fig. 13(a). Coarse-grained: one queue per device.
#[derive(Debug, Default)]
pub struct Eager;

impl Policy for Eager {
    fn name(&self) -> &'static str {
        "eager"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        let comp = *view.frontier.first()?;
        let dev = *view.available.first()?;
        Some((comp, dev))
    }

    fn queues_for(&self, _device: &Device) -> usize {
        1
    }
}

/// Dynamic *HEFT* (Expt 3): highest-ranked kernel onto the device with the
/// earliest finishing time, using profiled execution times. Willing to wait
/// for a busy-but-faster device (hence GEMMs stay on the GPU, Fig. 13(b)).
/// Coarse-grained: one queue per device.
#[derive(Debug, Default)]
pub struct Heft;

impl Policy for Heft {
    fn name(&self) -> &'static str {
        "heft"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        let comp = *view.frontier.first()?;
        // argmin over ALL devices of EFT = max(now, est_free) + exec.
        let mut best: Option<(DeviceId, f64)> = None;
        for d in &view.platform.devices {
            if d.num_queues == 0 {
                continue;
            }
            let eft = view.est_free[d.id].max(view.now) + view.component_time(comp, d);
            if best.map(|(_, t)| eft < t).unwrap_or(true) {
                best = Some((d.id, eft));
            }
        }
        let (dev, _) = best?;
        // Dispatch only once the EFT-optimal device is actually free;
        // otherwise block (the component keeps its frontier slot).
        if view.available.contains(&dev) {
            Some((comp, dev))
        } else {
            None
        }
    }

    fn queues_for(&self, _device: &Device) -> usize {
        1
    }
}

/// Load-aware serving policy: like [`Clustering`] it honours device-type
/// preference, but among matching candidates it picks the device carrying
/// the least cross-DAG occupancy (ties broken by earliest `est_free`) — the
/// natural `select` for multi-tenant platforms with several GPUs serving
/// concurrent requests.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Policy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        for &comp in view.frontier {
            let want = view.partition.components[comp].dev;
            let best = view
                .available
                .iter()
                .copied()
                .filter(|&d| view.platform.device(d).dtype == want)
                .min_by(|&a, &b| {
                    view.device_load[a]
                        .total_cmp(&view.device_load[b])
                        .then_with(|| view.est_free[a].total_cmp(&view.est_free[b]))
                });
            if let Some(dev) = best {
                return Some((comp, dev));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::platform::DeviceType;
    use crate::transformer::{cluster_by_head, transformer_dag};

    fn view_fixture<'a>(
        dag: &'a Dag,
        part: &'a Partition,
        platform: &'a Platform,
        frontier: &'a [usize],
        available: &'a [DeviceId],
        est_free: &'a [f64],
        device_load: &'a [f64],
    ) -> SchedView<'a> {
        SchedView {
            now: 0.0,
            frontier,
            available,
            platform,
            partition: part,
            dag,
            est_free,
            device_load,
            cost: &PaperCost,
        }
    }

    #[test]
    fn clustering_respects_device_preference() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 1); // head 0 on CPU
        let platform = Platform::paper_testbed(2, 1);
        let frontier = [0usize, 1];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        // Only the CPU (device 1) available: must pick comp 0 (cpu-pref).
        let v = view_fixture(&dag, &part, &platform, &frontier, &[1], &est, &load);
        assert_eq!(Clustering.select(&v), Some((0, 1)));
        // Only the GPU available: must skip comp 0 and pick comp 1.
        let v = view_fixture(&dag, &part, &platform, &frontier, &[0], &est, &load);
        assert_eq!(Clustering.select(&v), Some((1, 0)));
        // Nothing available: block.
        let v = view_fixture(&dag, &part, &platform, &frontier, &[], &est, &load);
        assert_eq!(Clustering.select(&v), None);
    }

    #[test]
    fn eager_ignores_preference() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0); // all GPU-pref
        let platform = Platform::paper_testbed(1, 1);
        let frontier = [0usize, 1];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        // CPU-only availability: eager still dispatches there.
        let v = view_fixture(&dag, &part, &platform, &frontier, &[1], &est, &load);
        assert_eq!(Eager.select(&v), Some((0, 1)));
        assert_eq!(Eager.queues_for(platform.device(0)), 1);
    }

    #[test]
    fn heft_waits_for_faster_busy_device() {
        let (dag, ios) = transformer_dag(1, 256, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(1, 1);
        let frontier = [0usize];
        let load = [0.0, 0.0];
        // GPU busy for a short while; CPU idle. GEMM component is far
        // faster on the GPU, so HEFT blocks rather than take the CPU.
        let est = [0.005, 0.0];
        let v = view_fixture(&dag, &part, &platform, &frontier, &[1], &est, &load);
        assert_eq!(Heft.select(&v), None);
        // Once the GPU frees, it dispatches there.
        let est = [0.0, 0.0];
        let v = view_fixture(&dag, &part, &platform, &frontier, &[0, 1], &est, &load);
        assert_eq!(Heft.select(&v), Some((0, 0)));
    }

    #[test]
    fn heft_takes_cpu_when_gpu_backlog_huge() {
        let (dag, ios) = transformer_dag(1, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(1, 1);
        let frontier = [0usize];
        let est = [100.0, 0.0]; // GPU booked out for 100 s
        let load = [0.0, 0.0];
        let v = view_fixture(&dag, &part, &platform, &frontier, &[1], &est, &load);
        assert_eq!(Heft.select(&v), Some((0, 1)));
    }

    #[test]
    fn least_loaded_spreads_across_matching_devices() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0); // both components GPU-pref
        let platform = Platform::scaled(2, 1, 3, 1); // two GPUs + one CPU
        let frontier = [0usize, 1];
        let est = [0.0, 0.0, 0.0];
        // GPU 0 is half loaded, GPU 1 idle: pick GPU 1.
        let load = [0.5, 0.0, 0.0];
        let v = view_fixture(&dag, &part, &platform, &frontier, &[0, 1, 2], &est, &load);
        assert_eq!(LeastLoaded.select(&v), Some((0, 1)));
        // Only the CPU available: a GPU-pref component blocks (preference
        // honoured, unlike eager).
        let v = view_fixture(&dag, &part, &platform, &frontier, &[2], &est, &load);
        assert_eq!(LeastLoaded.select(&v), None);
    }
}
