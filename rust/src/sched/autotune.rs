//! Auto-tuning of mapping configurations (the paper's §7 future work:
//! "an auto-tuning framework on top of PySchedCL which would automatically
//! determine, given an application-architecture pair, the optimal
//! allocation of command queues across devices").
//!
//! Two strategies over the `mc = ⟨q_gpu, q_cpu, h_cpu⟩` space:
//! * [`exhaustive`] — the Expt-1 sweep;
//! * [`hill_climb`] — greedy coordinate descent with restarts, evaluating a
//!   small fraction of the space (useful when a sim evaluation is costly or
//!   when tuning on the real executor).

use crate::cost::CostModel;
use crate::error::Result;
use crate::report::experiments::{run_clustering, MappingConfig};

/// Search-space bounds.
#[derive(Debug, Clone, Copy)]
pub struct TuneSpace {
    pub max_queues: usize,
    pub max_h_cpu: usize,
}

impl Default for TuneSpace {
    fn default() -> Self {
        TuneSpace {
            max_queues: 5,
            max_h_cpu: 3,
        }
    }
}

/// Tuning outcome: the best configuration found, its makespan (seconds) and
/// the number of evaluations spent.
#[derive(Debug, Clone, Copy)]
pub struct TuneResult {
    pub best: MappingConfig,
    pub makespan: f64,
    pub evals: usize,
}

fn valid(mc: MappingConfig) -> bool {
    mc.q_gpu >= 1 && !(mc.h_cpu > 0 && mc.q_cpu == 0)
}

/// Exhaustive sweep (ground truth; what Expt 1 reports).
pub fn exhaustive(
    heads: usize,
    beta: u64,
    space: TuneSpace,
    cost: &dyn CostModel,
) -> Result<TuneResult> {
    let mut best: Option<(MappingConfig, f64)> = None;
    let mut evals = 0;
    for q_gpu in 1..=space.max_queues {
        for q_cpu in 0..=space.max_queues {
            for h_cpu in 0..=heads.min(space.max_h_cpu) {
                let mc = MappingConfig {
                    q_gpu,
                    q_cpu,
                    h_cpu,
                };
                if !valid(mc) {
                    continue;
                }
                let t = run_clustering(heads, beta, mc, cost)?.makespan;
                evals += 1;
                if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                    best = Some((mc, t));
                }
            }
        }
    }
    let (best, makespan) = best.expect("non-empty space");
    Ok(TuneResult {
        best,
        makespan,
        evals,
    })
}

/// Greedy coordinate descent from a starting point: tweak one coordinate at
/// a time (±1), keep improvements, stop at a local optimum.
pub fn hill_climb(
    heads: usize,
    beta: u64,
    space: TuneSpace,
    start: MappingConfig,
    cost: &dyn CostModel,
) -> Result<TuneResult> {
    let mut evals = 0;
    let mut eval = |mc: MappingConfig| -> Result<Option<f64>> {
        if !valid(mc)
            || mc.q_gpu > space.max_queues
            || mc.q_cpu > space.max_queues
            || mc.h_cpu > heads.min(space.max_h_cpu)
        {
            return Ok(None);
        }
        evals += 1;
        Ok(Some(run_clustering(heads, beta, mc, cost)?.makespan))
    };
    let mut cur = start;
    let mut cur_t = eval(cur)?.expect("start must be valid");
    loop {
        let mut improved = false;
        let neighbours = [
            MappingConfig { q_gpu: cur.q_gpu + 1, ..cur },
            MappingConfig { q_gpu: cur.q_gpu.saturating_sub(1), ..cur },
            MappingConfig { q_cpu: cur.q_cpu + 1, ..cur },
            MappingConfig { q_cpu: cur.q_cpu.saturating_sub(1), ..cur },
            MappingConfig { h_cpu: cur.h_cpu + 1, ..cur },
            MappingConfig { h_cpu: cur.h_cpu.saturating_sub(1), ..cur },
            // Diagonal move: offloading the first head needs a CPU queue in
            // the same step (h_cpu > 0 with q_cpu = 0 is invalid).
            MappingConfig {
                q_cpu: cur.q_cpu + 1,
                h_cpu: cur.h_cpu + 1,
                ..cur
            },
        ];
        for n in neighbours {
            if n == cur {
                continue;
            }
            if let Some(t) = eval(n)? {
                if t < cur_t {
                    cur = n;
                    cur_t = t;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }
    Ok(TuneResult {
        best: cur,
        makespan: cur_t,
        evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::report::experiments::DEFAULT_MC;

    #[test]
    fn exhaustive_finds_known_optimum_shape() {
        let space = TuneSpace {
            max_queues: 3,
            max_h_cpu: 1,
        };
        let r = exhaustive(12, 256, space, &PaperCost).unwrap();
        // At H=12, offloading one head wins (Fig. 11).
        assert_eq!(r.best.h_cpu, 1);
        assert!(r.best.q_gpu >= 2, "fine-grained queues should win");
        assert!(r.evals > 10);
    }

    #[test]
    fn hill_climb_matches_exhaustive_with_fewer_evals() {
        let space = TuneSpace {
            max_queues: 3,
            max_h_cpu: 1,
        };
        let ex = exhaustive(12, 256, space, &PaperCost).unwrap();
        let hc = hill_climb(12, 256, space, DEFAULT_MC, &PaperCost).unwrap();
        assert!(hc.evals < ex.evals, "{} !< {}", hc.evals, ex.evals);
        // Within 5% of the global optimum from the default start.
        assert!(hc.makespan <= ex.makespan * 1.05);
    }

    #[test]
    fn hill_climb_never_returns_invalid() {
        let r = hill_climb(4, 128, TuneSpace::default(), DEFAULT_MC, &PaperCost).unwrap();
        assert!(r.best.q_gpu >= 1);
        assert!(!(r.best.h_cpu > 0 && r.best.q_cpu == 0));
    }
}
