//! The incrementally maintained scheduler core (`SchedState`).
//!
//! Until PR 5 every [`Policy::select`](super::Policy::select) call received
//! a freshly materialized [`super::reference::SchedView`] and linearly
//! scanned the whole frontier — O(F) per decision, the dominant
//! blocked-phase cost under sustained overload backlogs (thousands of
//! resident frontier entries). `SchedState` replaces the rebuild-per-call
//! view with **indexed scheduler state updated by narrow events**:
//!
//! * [`SchedState::on_ready`] — a component joined the frontier;
//! * [`SchedState::on_dispatch`] — a component left the frontier for a
//!   device (tenant accounting + availability);
//! * [`SchedState::on_complete`] — a resident component finished (tenant
//!   slot returned);
//! * [`SchedState::on_preempt`] — a resident component was displaced (the
//!   caller re-enters it via `on_ready`).
//!
//! Internally the frontier lives in **per-device-type buckets**, each
//! holding three heaps:
//!
//! * a *rank heap* ordered by (bottom-level rank desc, entry seq asc) —
//!   exactly the rank-sorted frontier order the view-based policies
//!   scanned (`clustering`, `eager`, `heft`, `least-loaded`, and `edf`'s
//!   metadata-free fallback);
//! * a *deadline heap* over finite-deadline components ordered by absolute
//!   deadline — the EDF urgency head; exact deadline ties are resolved at
//!   select time with the same laxity/priority/frontier-order tie-break
//!   the reference comparator uses (laxity depends on `now`, so it cannot
//!   be a static heap key — but on equal deadlines the laxity *order* only
//!   depends on static component times, and the values are recomputed with
//!   the reference float-op order so the comparison is bit-identical);
//! * a *fallback heap* over ∞-deadline components ordered by (priority
//!   desc, rank desc, seq asc) — the statically known remainder of the
//!   urgency order (∞-deadline laxities are always the ∞ placeholder).
//!
//! Removal is **lazy**: each frontier entry carries the sequence number it
//! was inserted with, and `entry_seq`/`in_frontier` invalidate stale heap
//! entries on peek (a preempted component re-enters with a fresh seq, so
//! its old entries are skipped). Every event is O(log F); every shipped
//! policy's `select` is O(log F) plus O(#devices) for the device choice.
//!
//! Cached device state rides along: the order-preserving available set
//! (policies depend on its FIFO order), per-type availability counts,
//! tenancy counters, `est_free` EFT bookkeeping, and the cross-DAG
//! `device_load` signal the engines refresh incrementally.
//!
//! Both execution engines drive one `SchedState` ([`crate::sim`] feeds it
//! the frontier deltas its event loop already computes; the real
//! [`crate::exec`] executor mutates it under its scheduler lock), so sim
//! and real share a single scheduler core.

use crate::cost::CostModel;
use crate::error::{Error, Result};
use crate::graph::{Dag, Partition};
use crate::platform::{Device, DeviceId, DeviceType, Platform};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Number of device-type buckets ([`DeviceType`] is `Gpu | Cpu`).
const NTYPES: usize = 2;

/// Bucket index of a device type.
fn ti(t: DeviceType) -> usize {
    match t {
        DeviceType::Gpu => 0,
        DeviceType::Cpu => 1,
    }
}

/// Rank-bucket entry: max-heap order = frontier order (rank descending,
/// insertion seq ascending — ties between equal ranks stay FIFO, exactly
/// the stable order the view-based frontier `Vec` maintained).
#[derive(Clone, Copy)]
struct RankEntry {
    rank: f64,
    seq: u64,
    comp: usize,
}

impl PartialEq for RankEntry {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}
impl Eq for RankEntry {}
impl PartialOrd for RankEntry {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for RankEntry {
    fn cmp(&self, o: &Self) -> Ordering {
        self.rank
            .total_cmp(&o.rank)
            .then_with(|| o.seq.cmp(&self.seq))
    }
}

/// Deadline-bucket entry: max-heap order = earliest absolute deadline
/// first. Ties are *not* decided here — the select path collects every
/// entry tied at the minimum deadline and applies the full urgency
/// tie-break (laxity, priority, frontier order) itself.
#[derive(Clone, Copy)]
struct DlEntry {
    deadline: f64,
    seq: u64,
    comp: usize,
}

impl PartialEq for DlEntry {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}
impl Eq for DlEntry {}
impl PartialOrd for DlEntry {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for DlEntry {
    fn cmp(&self, o: &Self) -> Ordering {
        o.deadline
            .total_cmp(&self.deadline)
            .then_with(|| o.seq.cmp(&self.seq))
    }
}

/// Fallback-bucket entry (∞-deadline components): max-heap order =
/// urgency order restricted to that population — priority descending,
/// then frontier order (rank desc, seq asc). Static, because ∞-deadline
/// laxities are always the ∞ placeholder in the reference comparator.
#[derive(Clone, Copy)]
struct FbEntry {
    priority: u32,
    rank: f64,
    seq: u64,
    comp: usize,
}

impl PartialEq for FbEntry {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}
impl Eq for FbEntry {}
impl PartialOrd for FbEntry {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for FbEntry {
    fn cmp(&self, o: &Self) -> Ordering {
        self.priority
            .cmp(&o.priority)
            .then_with(|| self.rank.total_cmp(&o.rank))
            .then_with(|| o.seq.cmp(&self.seq))
    }
}

/// Prune stale heads (component left the frontier, or re-entered with a
/// newer seq) and return the current valid head without removing it.
macro_rules! prune_peek {
    ($heap:expr, $in_frontier:expr, $entry_seq:expr) => {{
        loop {
            match $heap.peek() {
                None => break None,
                Some(e) => {
                    if $in_frontier[e.comp] && $entry_seq[e.comp] == e.seq {
                        break Some(*e);
                    }
                }
            }
            $heap.pop();
        }
    }};
}

/// Incrementally maintained scheduler state shared by the simulator and
/// the real executor — see the module docs for the index layout. Public
/// fields are the raw scheduler inputs the engines own (`now`, `est_free`,
/// `device_load`, serving metadata); the frontier and availability indexes
/// are private and only change through the event API.
pub struct SchedState<'a> {
    /// Current scheduling instant (virtual time in the simulator, seconds
    /// since the call epoch in the real executor). The engine sets this
    /// before every scheduler phase; EDF laxities are computed against it.
    pub now: f64,
    pub platform: &'a Platform,
    pub partition: &'a Partition,
    pub dag: &'a Dag,
    pub cost: &'a dyn CostModel,
    /// Estimated time each device becomes free (≤ now when idle) — HEFT's
    /// EFT bookkeeping, maintained by the engines.
    pub est_free: Vec<f64>,
    /// Cross-DAG busyness per device (Σ occupancy of running kernels in
    /// the simulator; resident-fraction in the real executor). Policies
    /// compare devices *relatively*; engines refresh it incrementally.
    pub device_load: Vec<f64>,
    /// Resident-component count per device (multi-tenant serving).
    pub tenants: Vec<usize>,
    /// Absolute deadline per component (∞ when the request carries none).
    pub deadline: Vec<f64>,
    /// Request priority per component (larger = more urgent; 0 default).
    pub priority: Vec<u32>,

    /// Residents a device admits before it leaves the available set.
    tenancy: usize,
    comp_rank: Vec<f64>,
    comp_pref: Vec<DeviceType>,
    /// Device backing [`SchedState::laxity`] per component (preferred-type
    /// device, first platform device as fallback) and the memoized solo
    /// component time on it — static, so laxity is O(1) per query.
    lax_dev: Vec<Option<DeviceId>>,
    lax_time: Vec<f64>,

    /// Available (idle/under-tenancy) devices, **order-preserving**: the
    /// FIFO add/remove order the view-based policies scanned. Device
    /// choice rules (`first available of type`, `least-loaded of type`)
    /// depend on this order for their tie-breaks.
    available: Vec<DeviceId>,
    dev_available: Vec<bool>,
    avail_per_type: [usize; NTYPES],
    /// Crashed devices ([`SchedState::on_device_down`]): excluded from the
    /// available set regardless of tenancy until
    /// [`SchedState::on_device_up`] clears the flag.
    dev_down: Vec<bool>,

    in_frontier: Vec<bool>,
    entry_seq: Vec<u64>,
    next_seq: u64,
    frontier_len: usize,
    /// Frontier components carrying urgency metadata (finite deadline or
    /// non-default priority) — EDF's "any metadata at all?" fast path.
    meta_carriers: usize,

    rank_heap: [BinaryHeap<RankEntry>; NTYPES],
    dl_heap: [BinaryHeap<DlEntry>; NTYPES],
    fb_heap: [BinaryHeap<FbEntry>; NTYPES],
    /// Scratch for deadline-tie collection (reused across selects).
    tie_scratch: Vec<DlEntry>,

    /// Streaming slot mode ([`SchedState::for_streaming`]): component ids
    /// are reusable *slots* owned by the streaming simulator, not indices
    /// into `partition`/`dag` (which are empty placeholders). Per-slot
    /// facts arrive via [`SchedState::set_slot`]; [`SchedState::component_time`]
    /// reads the memoized per-device table instead of walking the DAG.
    slot_mode: bool,
    /// Slot mode only: solo component time per `[slot * ndev + device id]`,
    /// precomputed at admission with the same kernel-order sum as the
    /// non-slot `component_time` (bit-identical values).
    slot_times: Vec<f64>,
}

impl<'a> SchedState<'a> {
    /// Build the indexed state for one scheduling run. `tenancy` is the
    /// per-device resident cap (≥ 1); `deadline`/`priority` are the
    /// per-component serving metadata (static for the run). Errors when no
    /// platform device has command queues — the same guard both engines
    /// applied.
    pub fn new(
        dag: &'a Dag,
        partition: &'a Partition,
        platform: &'a Platform,
        cost: &'a dyn CostModel,
        tenancy: usize,
        deadline: Vec<f64>,
        priority: Vec<u32>,
    ) -> Result<SchedState<'a>> {
        let ncomp = partition.components.len();
        let ndev = platform.devices.len();
        let available: Vec<DeviceId> = platform
            .devices
            .iter()
            .filter(|d| d.num_queues > 0)
            .map(|d| d.id)
            .collect();
        if available.is_empty() {
            return Err(Error::Sched("no device has command queues".into()));
        }
        let mut dev_available = vec![false; ndev];
        let mut avail_per_type = [0usize; NTYPES];
        for &d in &available {
            dev_available[d] = true;
            avail_per_type[ti(platform.device(d).dtype)] += 1;
        }
        let comp_rank = super::component_ranks(dag, partition, platform, cost);
        let comp_pref: Vec<DeviceType> = partition.components.iter().map(|c| c.dev).collect();
        let lax_dev: Vec<Option<DeviceId>> = partition
            .components
            .iter()
            .map(|c| {
                platform
                    .devices
                    .iter()
                    .find(|d| d.dtype == c.dev)
                    .or_else(|| platform.devices.first())
                    .map(|d| d.id)
            })
            .collect();
        let lax_time: Vec<f64> = (0..ncomp)
            .map(|c| match lax_dev[c] {
                Some(d) => {
                    let dev = platform.device(d);
                    partition.components[c]
                        .kernels
                        .iter()
                        .map(|&k| cost.exec_time(&dag.kernels[k], dev))
                        .sum()
                }
                None => 0.0,
            })
            .collect();
        Ok(SchedState {
            now: 0.0,
            platform,
            partition,
            dag,
            cost,
            est_free: vec![0.0; ndev],
            device_load: vec![0.0; ndev],
            tenants: vec![0; ndev],
            deadline,
            priority,
            tenancy: tenancy.max(1),
            comp_rank,
            comp_pref,
            lax_dev,
            lax_time,
            available,
            dev_available,
            avail_per_type,
            dev_down: vec![false; ndev],
            in_frontier: vec![false; ncomp],
            entry_seq: vec![0; ncomp],
            next_seq: 0,
            frontier_len: 0,
            meta_carriers: 0,
            rank_heap: [BinaryHeap::new(), BinaryHeap::new()],
            dl_heap: [BinaryHeap::new(), BinaryHeap::new()],
            fb_heap: [BinaryHeap::new(), BinaryHeap::new()],
            tie_scratch: Vec::new(),
            slot_mode: false,
            slot_times: Vec::new(),
        })
    }

    // -------------------------------------------------- streaming slot mode

    /// Build a **slot-mode** state for the always-on streaming simulator
    /// ([`crate::sim::stream`]): one persistent `SchedState` whose
    /// component ids are reusable slots, delta-updated as requests are
    /// admitted and retired, instead of a state rebuilt per merged app.
    /// `dag`/`partition` are caller-owned empty placeholders (slot mode
    /// never reads them); per-slot metadata arrives via
    /// [`SchedState::set_slot`] and every per-slot vector grows to the
    /// peak live-slot count, **not** the stream length — the bounded-memory
    /// contract.
    pub fn for_streaming(
        dag: &'a Dag,
        partition: &'a Partition,
        platform: &'a Platform,
        cost: &'a dyn CostModel,
        tenancy: usize,
    ) -> Result<SchedState<'a>> {
        let mut st = Self::new(dag, partition, platform, cost, tenancy, Vec::new(), Vec::new())?;
        st.slot_mode = true;
        Ok(st)
    }

    /// (Re)bind slot `slot` to a newly admitted component's static facts:
    /// bottom-level rank, preferred device type, serving metadata, and the
    /// solo component time per platform device (`dev_times[d]`, indexed by
    /// device id — also the source of the laxity memo: the laxity device is
    /// the first device of the preferred type, first platform device as
    /// fallback, exactly as [`SchedState::new`] derives it). The slot must
    /// not currently be in the frontier. Slots are dense and reusable:
    /// setting slot `n` with `n == live capacity` grows every per-slot
    /// vector by one; setting a retired slot overwrites in place.
    pub fn set_slot(
        &mut self,
        slot: usize,
        rank: f64,
        pref: DeviceType,
        deadline: f64,
        priority: u32,
        dev_times: &[f64],
    ) {
        debug_assert!(self.slot_mode, "set_slot outside streaming slot mode");
        debug_assert_eq!(dev_times.len(), self.platform.devices.len());
        let ndev = self.platform.devices.len();
        if slot >= self.comp_rank.len() {
            debug_assert_eq!(slot, self.comp_rank.len(), "slots must stay dense");
            self.comp_rank.push(0.0);
            self.comp_pref.push(DeviceType::Gpu);
            self.lax_dev.push(None);
            self.lax_time.push(0.0);
            self.deadline.push(f64::INFINITY);
            self.priority.push(0);
            self.in_frontier.push(false);
            self.entry_seq.push(0);
            self.slot_times.extend(std::iter::repeat(0.0).take(ndev));
        }
        debug_assert!(!self.in_frontier[slot], "rebinding a live frontier slot");
        self.comp_rank[slot] = rank;
        self.comp_pref[slot] = pref;
        self.deadline[slot] = deadline;
        self.priority[slot] = priority;
        let lax_dev = self
            .platform
            .devices
            .iter()
            .find(|d| d.dtype == pref)
            .or_else(|| self.platform.devices.first())
            .map(|d| d.id);
        self.lax_dev[slot] = lax_dev;
        self.lax_time[slot] = match lax_dev {
            Some(d) => dev_times[d],
            None => 0.0,
        };
        self.slot_times[slot * ndev..(slot + 1) * ndev].copy_from_slice(dev_times);
    }

    /// Total entries currently held by the frontier heaps, live and stale.
    /// Lazy deletion leaves stale entries behind until a peek walks over
    /// them; under an unbounded stream the driver compares this against
    /// [`SchedState::frontier_len`] and triggers [`SchedState::compact_heaps`]
    /// so heap memory stays bounded by the live window, not the stream.
    pub fn heap_entries(&self) -> usize {
        (0..NTYPES)
            .map(|t| self.rank_heap[t].len() + self.dl_heap[t].len() + self.fb_heap[t].len())
            .sum()
    }

    /// Drop every stale (retired / re-entered) heap entry and rebuild the
    /// heaps from the live ones. Pop order is unchanged — entries order by
    /// (key, seq), a total order independent of heap layout — so compaction
    /// is behavior-neutral; it only reclaims memory. O(E) for E entries.
    pub fn compact_heaps(&mut self) {
        for t in 0..NTYPES {
            let live =
                |comp: usize, seq: u64| self.in_frontier[comp] && self.entry_seq[comp] == seq;
            let h = std::mem::take(&mut self.rank_heap[t]);
            self.rank_heap[t] = h.into_iter().filter(|e| live(e.comp, e.seq)).collect();
            let h = std::mem::take(&mut self.dl_heap[t]);
            self.dl_heap[t] = h.into_iter().filter(|e| live(e.comp, e.seq)).collect();
            let h = std::mem::take(&mut self.fb_heap[t]);
            self.fb_heap[t] = h.into_iter().filter(|e| live(e.comp, e.seq)).collect();
        }
    }

    // ------------------------------------------------------------- events

    /// A component became ready (dependencies met, request released) and
    /// joins the frontier. No-op when already present. O(log F).
    pub fn on_ready(&mut self, comp: usize) {
        if self.in_frontier[comp] {
            return;
        }
        self.in_frontier[comp] = true;
        self.frontier_len += 1;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.entry_seq[comp] = seq;
        let t = ti(self.comp_pref[comp]);
        let rank = self.comp_rank[comp];
        self.rank_heap[t].push(RankEntry { rank, seq, comp });
        if self.deadline[comp].is_finite() {
            self.dl_heap[t].push(DlEntry {
                deadline: self.deadline[comp],
                seq,
                comp,
            });
        } else {
            self.fb_heap[t].push(FbEntry {
                priority: self.priority[comp],
                rank,
                seq,
                comp,
            });
        }
        if self.carries_meta(comp) {
            self.meta_carriers += 1;
        }
    }

    /// The policy dispatched `comp` to `dev`: the component leaves the
    /// frontier and occupies one tenant slot; the device leaves the
    /// available set when it reaches the tenancy cap. O(log F) amortized
    /// (stale heap entries die lazily on later peeks).
    pub fn on_dispatch(&mut self, comp: usize, dev: DeviceId) {
        debug_assert!(self.in_frontier[comp], "dispatching a non-frontier component");
        self.frontier_leave(comp);
        self.tenants[dev] += 1;
        if self.tenants[dev] >= self.tenancy {
            self.device_remove(dev);
        }
    }

    /// A resident component on `dev` completed: the tenant slot returns
    /// and the device re-enters the available set.
    pub fn on_complete(&mut self, dev: DeviceId) {
        self.tenants[dev] -= 1;
        self.device_add(dev);
    }

    /// A resident component on `dev` was displaced mid-flight: the tenant
    /// slot returns immediately. The caller re-enters the victim via
    /// [`SchedState::on_ready`] (it gets a fresh entry seq, so its stale
    /// heap entries are skipped).
    pub fn on_preempt(&mut self, dev: DeviceId) {
        self.tenants[dev] -= 1;
        self.device_add(dev);
    }

    fn frontier_leave(&mut self, comp: usize) {
        if !self.in_frontier[comp] {
            return;
        }
        self.in_frontier[comp] = false;
        self.frontier_len -= 1;
        if self.carries_meta(comp) {
            self.meta_carriers -= 1;
        }
    }

    fn carries_meta(&self, comp: usize) -> bool {
        self.deadline[comp].is_finite() || self.priority[comp] > 0
    }

    // ------------------------------------------------------ device state

    /// Return `dev` to the available set (no-op if present), preserving
    /// FIFO order exactly as the view-based engines did. A crashed device
    /// never re-enters — tenant slots returned by its displaced residents
    /// ([`SchedState::on_preempt`]/[`SchedState::on_complete`]) must not
    /// resurrect it.
    fn device_add(&mut self, dev: DeviceId) {
        if self.dev_down[dev] {
            return;
        }
        if !self.dev_available[dev] {
            self.dev_available[dev] = true;
            self.available.push(dev);
            self.avail_per_type[ti(self.platform.device(dev).dtype)] += 1;
        }
    }

    /// Remove `dev` from the available set (no-op if absent), preserving
    /// the order of the remaining entries.
    fn device_remove(&mut self, dev: DeviceId) {
        if !self.dev_available[dev] {
            return;
        }
        self.dev_available[dev] = false;
        self.avail_per_type[ti(self.platform.device(dev).dtype)] -= 1;
        let pos = self
            .available
            .iter()
            .position(|&d| d == dev)
            .expect("bitset says dev is available");
        self.available.remove(pos);
    }

    /// Force `dev` out of the available set without touching tenancy —
    /// test/bench scaffolding for constructing specific availability
    /// pictures (the engines only move devices through the event API).
    #[doc(hidden)]
    pub fn mark_unavailable(&mut self, dev: DeviceId) {
        self.device_remove(dev);
    }

    /// `dev` crashed (fault injection / watchdog): leave the available set
    /// and stay out until [`SchedState::on_device_up`]. Tenancy counts are
    /// untouched — the engine displaces each resident, whose
    /// [`SchedState::on_preempt`] returns the tenant slot without
    /// resurrecting the device (see [`device_add`](Self::device_add)).
    /// No-op when already down.
    pub fn on_device_down(&mut self, dev: DeviceId) {
        if self.dev_down[dev] {
            return;
        }
        self.dev_down[dev] = true;
        self.device_remove(dev);
    }

    /// `dev` recovered: clear the down flag and re-enter the available set
    /// if it is eligible (has command queues, under the tenancy cap).
    /// No-op when not down.
    pub fn on_device_up(&mut self, dev: DeviceId) {
        if !self.dev_down[dev] {
            return;
        }
        self.dev_down[dev] = false;
        if self.platform.device(dev).num_queues > 0 && self.tenants[dev] < self.tenancy {
            self.device_add(dev);
        }
    }

    /// Is `dev` marked crashed?
    pub fn is_down(&self, dev: DeviceId) -> bool {
        self.dev_down[dev]
    }

    /// A frontier component was shed (graceful degradation): it leaves the
    /// frontier without being dispatched. No-op when not in the frontier.
    pub fn on_shed(&mut self, comp: usize) {
        self.frontier_leave(comp);
    }

    // ------------------------------------------------------------ queries

    /// The available-device set, in the FIFO order policies scan.
    pub fn available(&self) -> &[DeviceId] {
        &self.available
    }

    pub fn is_available(&self, dev: DeviceId) -> bool {
        self.dev_available[dev]
    }

    /// Whether any device of type `t` is currently available.
    pub fn has_available(&self, t: DeviceType) -> bool {
        self.avail_per_type[ti(t)] > 0
    }

    pub fn frontier_len(&self) -> usize {
        self.frontier_len
    }

    pub fn frontier_is_empty(&self) -> bool {
        self.frontier_len == 0
    }

    pub fn in_frontier(&self, comp: usize) -> bool {
        self.in_frontier[comp]
    }

    /// Frontier components carrying urgency metadata (finite deadline or
    /// non-default priority).
    pub fn meta_carriers(&self) -> usize {
        self.meta_carriers
    }

    /// `comp`'s preferred device type.
    pub fn pref(&self, comp: usize) -> DeviceType {
        self.comp_pref[comp]
    }

    /// `comp`'s bottom-level rank.
    pub fn rank(&self, comp: usize) -> f64 {
        self.comp_rank[comp]
    }

    /// Solo execution-time estimate of a whole component on a device —
    /// the same kernel-order sum the view API exposed. In streaming slot
    /// mode the value comes from the per-slot table filled by
    /// [`SchedState::set_slot`] (the placeholder `partition`/`dag` are
    /// empty); the table is computed with the identical kernel-order sum,
    /// so policies read bit-identical values either way.
    pub fn component_time(&self, comp: usize, dev: &Device) -> f64 {
        if self.slot_mode {
            return self.slot_times[comp * self.platform.devices.len() + dev.id];
        }
        self.partition.components[comp]
            .kernels
            .iter()
            .map(|&k| self.cost.exec_time(&self.dag.kernels[k], dev))
            .sum()
    }

    /// Laxity of `comp` at the current `now`: slack between its absolute
    /// deadline and its estimated completion were it dispatched now on a
    /// device of its preferred type (+∞ for deadline-free components).
    /// O(1) — the component time on the laxity device is memoized; the
    /// float-op order matches the view-based computation bit for bit.
    pub fn laxity(&self, comp: usize) -> f64 {
        if self.deadline[comp].is_infinite() {
            return f64::INFINITY;
        }
        match self.lax_dev[comp] {
            Some(_) => self.deadline[comp] - self.now - self.lax_time[comp],
            None => f64::INFINITY,
        }
    }

    /// First available device of type `t`, in available-set order — the
    /// clustering device rule.
    pub fn first_available_of(&self, t: DeviceType) -> Option<DeviceId> {
        self.available
            .iter()
            .copied()
            .find(|&d| self.platform.device(d).dtype == t)
    }

    /// Least-loaded available device of type `t` (ties broken by earliest
    /// `est_free`, then available-set order) — the serving device rule
    /// shared by `least-loaded` and `edf`.
    pub fn least_loaded_available_of(&self, t: DeviceType) -> Option<DeviceId> {
        self.available
            .iter()
            .copied()
            .filter(|&d| self.platform.device(d).dtype == t)
            .min_by(|&a, &b| {
                self.device_load[a]
                    .total_cmp(&self.device_load[b])
                    .then_with(|| self.est_free[a].total_cmp(&self.est_free[b]))
            })
    }

    // ----------------------------------------------------- frontier heads

    fn rank_peek(&mut self, t: usize) -> Option<RankEntry> {
        prune_peek!(&mut self.rank_heap[t], self.in_frontier, self.entry_seq)
    }

    fn dl_peek(&mut self, t: usize) -> Option<DlEntry> {
        prune_peek!(&mut self.dl_heap[t], self.in_frontier, self.entry_seq)
    }

    fn fb_peek(&mut self, t: usize) -> Option<FbEntry> {
        prune_peek!(&mut self.fb_heap[t], self.in_frontier, self.entry_seq)
    }

    /// Head of the whole frontier in rank order — `frontier[0]` of the
    /// view API. O(log F).
    pub fn rank_head(&mut self) -> Option<usize> {
        let mut best: Option<RankEntry> = None;
        for t in 0..NTYPES {
            if let Some(e) = self.rank_peek(t) {
                if best.map(|b| e > b).unwrap_or(true) {
                    best = Some(e);
                }
            }
        }
        best.map(|e| e.comp)
    }

    /// First frontier component (rank order) whose preferred device type
    /// currently has an available device — the component the view-based
    /// `clustering`/`least-loaded` scan found in O(F), now O(log F).
    pub fn rank_head_placeable(&mut self) -> Option<usize> {
        let mut best: Option<RankEntry> = None;
        for t in 0..NTYPES {
            if self.avail_per_type[t] == 0 {
                continue;
            }
            if let Some(e) = self.rank_peek(t) {
                if best.map(|b| e > b).unwrap_or(true) {
                    best = Some(e);
                }
            }
        }
        best.map(|e| e.comp)
    }

    /// Most urgent frontier component in the full EDF order (deadline asc,
    /// laxity asc on exact deadline ties, priority desc, frontier order).
    /// With `require_available`, only components whose preferred type has
    /// an available device are considered (the "first placeable in urgency
    /// order" step of a blocked EDF round). O(T · log F) where T is the
    /// number of components tied bitwise at the minimum deadline.
    pub fn urgency_head(&mut self, require_available: bool) -> Option<usize> {
        // Minimum finite deadline across the considered buckets.
        let mut min_dl: Option<f64> = None;
        for t in 0..NTYPES {
            if require_available && self.avail_per_type[t] == 0 {
                continue;
            }
            if let Some(e) = self.dl_peek(t) {
                min_dl = Some(match min_dl {
                    None => e.deadline,
                    Some(m) if e.deadline.total_cmp(&m).is_lt() => e.deadline,
                    Some(m) => m,
                });
            }
        }
        if let Some(d0) = min_dl {
            // Collect every entry tied bitwise at d0 (lazy-stale entries
            // were already pruned by dl_peek above), resolve the tie with
            // the reference comparator, then restore the entries — select
            // must not consume the frontier.
            let mut tied = std::mem::take(&mut self.tie_scratch);
            tied.clear();
            for t in 0..NTYPES {
                if require_available && self.avail_per_type[t] == 0 {
                    continue;
                }
                while let Some(e) = self.dl_peek(t) {
                    if e.deadline.total_cmp(&d0).is_ne() {
                        break;
                    }
                    self.dl_heap[t].pop();
                    tied.push(e);
                }
            }
            let mut best: Option<(usize, f64)> = None;
            for e in tied.iter() {
                let lax = self.laxity(e.comp);
                let better = match best {
                    None => true,
                    Some((b, bl)) => {
                        lax.total_cmp(&bl)
                            .then_with(|| self.priority[b].cmp(&self.priority[e.comp]))
                            .then_with(|| {
                                self.comp_rank[b].total_cmp(&self.comp_rank[e.comp])
                            })
                            .then_with(|| {
                                self.entry_seq[e.comp].cmp(&self.entry_seq[b])
                            })
                            .is_lt()
                    }
                };
                if better {
                    best = Some((e.comp, lax));
                }
            }
            for e in tied.iter() {
                self.dl_heap[ti(self.comp_pref[e.comp])].push(*e);
            }
            self.tie_scratch = tied;
            return best.map(|(c, _)| c);
        }
        // No finite deadlines in scope: the fallback heaps' static
        // (priority desc, rank desc, seq asc) order is the urgency order.
        let mut best: Option<FbEntry> = None;
        for t in 0..NTYPES {
            if require_available && self.avail_per_type[t] == 0 {
                continue;
            }
            if let Some(e) = self.fb_peek(t) {
                if best.map(|b| e > b).unwrap_or(true) {
                    best = Some(e);
                }
            }
        }
        best.map(|e| e.comp)
    }

    /// The full EDF urgency order between two (not necessarily frontier)
    /// components: deadline ascending, laxity ascending on ties, priority
    /// descending — [`super::reference::Edf`]'s `urgency_cmp`, served from
    /// the memoized laxity times.
    pub fn urgency_cmp(&self, a: usize, b: usize) -> Ordering {
        self.deadline[a]
            .total_cmp(&self.deadline[b])
            .then_with(|| self.laxity(a).total_cmp(&self.laxity(b)))
            .then_with(|| self.priority[b].cmp(&self.priority[a]))
    }

    /// The whole frontier in rank order — O(F log F), **not** a hot-path
    /// API. Escape hatch for custom policies that genuinely need to walk
    /// the frontier (see `examples/custom_scheduler.rs`) and for tests.
    pub fn frontier_ranked(&mut self) -> Vec<usize> {
        let mut entries: Vec<RankEntry> = Vec::with_capacity(self.frontier_len);
        for t in 0..NTYPES {
            entries.extend(
                self.rank_heap[t]
                    .iter()
                    .filter(|e| self.in_frontier[e.comp] && self.entry_seq[e.comp] == e.seq)
                    .copied(),
            );
        }
        entries.sort_by(|a, b| b.cmp(a));
        entries.into_iter().map(|e| e.comp).collect()
    }

    // ------------------------------------------------- ambiguity choice lists
    //
    // The concurrency fuzzer's instrumentation seam: the scheduler's
    // tie-break points surfaced as *explicit choice lists*. Each returns
    // every frontier component tied bitwise at the relevant head key, in
    // entry-seq (FIFO) order — the deterministic winner is element 0, and
    // any other element is a same-instant ordering the event loop could
    // have produced had the tied components entered the frontier in a
    // different order. `sched::fuzz` permutes frontier-entry batches and
    // uses these lists to prove the permutation actually moved a tie.

    /// Every frontier component tied bitwise with [`SchedState::rank_head`]
    /// (rank-order dispatch ties), FIFO order. Empty iff the frontier is.
    /// Peek-only: the frontier is left untouched.
    pub fn rank_head_ties(&mut self) -> Vec<usize> {
        let mut best: Option<RankEntry> = None;
        for t in 0..NTYPES {
            if let Some(e) = self.rank_peek(t) {
                if best.map(|b| e > b).unwrap_or(true) {
                    best = Some(e);
                }
            }
        }
        let Some(head) = best else {
            return Vec::new();
        };
        let mut tied: Vec<RankEntry> = Vec::new();
        for t in 0..NTYPES {
            let first = tied.len();
            while let Some(e) = self.rank_peek(t) {
                if e.rank.total_cmp(&head.rank).is_ne() {
                    break;
                }
                self.rank_heap[t].pop();
                tied.push(e);
            }
            for e in &tied[first..] {
                self.rank_heap[t].push(*e);
            }
        }
        tied.sort_by_key(|e| e.seq);
        tied.into_iter().map(|e| e.comp).collect()
    }

    /// Every frontier component tied bitwise at the urgency head's key
    /// (minimum finite deadline, or — when no finite deadline is in scope —
    /// the fallback heaps' (priority, rank) head), FIFO order. These are the
    /// EDF dispatch ties the select-time laxity/priority/frontier-order
    /// tie-break resolves; permuting their frontier-entry order permutes the
    /// final `seq` criterion. Peek-only.
    pub fn urgency_head_ties(&mut self, require_available: bool) -> Vec<usize> {
        let mut min_dl: Option<f64> = None;
        for t in 0..NTYPES {
            if require_available && self.avail_per_type[t] == 0 {
                continue;
            }
            if let Some(e) = self.dl_peek(t) {
                min_dl = Some(match min_dl {
                    None => e.deadline,
                    Some(m) if e.deadline.total_cmp(&m).is_lt() => e.deadline,
                    Some(m) => m,
                });
            }
        }
        if let Some(d0) = min_dl {
            let mut tied: Vec<DlEntry> = Vec::new();
            for t in 0..NTYPES {
                if require_available && self.avail_per_type[t] == 0 {
                    continue;
                }
                let first = tied.len();
                while let Some(e) = self.dl_peek(t) {
                    if e.deadline.total_cmp(&d0).is_ne() {
                        break;
                    }
                    self.dl_heap[t].pop();
                    tied.push(e);
                }
                for e in &tied[first..] {
                    self.dl_heap[t].push(*e);
                }
            }
            tied.sort_by_key(|e| e.seq);
            return tied.into_iter().map(|e| e.comp).collect();
        }
        let mut best: Option<FbEntry> = None;
        for t in 0..NTYPES {
            if require_available && self.avail_per_type[t] == 0 {
                continue;
            }
            if let Some(e) = self.fb_peek(t) {
                if best.map(|b| e > b).unwrap_or(true) {
                    best = Some(e);
                }
            }
        }
        let Some(head) = best else {
            return Vec::new();
        };
        let mut tied: Vec<FbEntry> = Vec::new();
        for t in 0..NTYPES {
            if require_available && self.avail_per_type[t] == 0 {
                continue;
            }
            let first = tied.len();
            while let Some(e) = self.fb_peek(t) {
                if e.priority != head.priority || e.rank.total_cmp(&head.rank).is_ne() {
                    break;
                }
                self.fb_heap[t].pop();
                tied.push(e);
            }
            for e in &tied[first..] {
                self.fb_heap[t].push(*e);
            }
        }
        tied.sort_by_key(|e| e.seq);
        tied.into_iter().map(|e| e.comp).collect()
    }

    /// The frontier-entry sequence number of `comp`, if it is currently in
    /// the frontier. Exposes the FIFO tier order for rebuild-equivalence
    /// oracles (a rebuilt state re-enters components in ascending entry
    /// seq to land in the same relative order).
    pub fn entry_seq_of(&self, comp: usize) -> Option<u64> {
        self.in_frontier[comp].then_some(self.entry_seq[comp])
    }

    /// Cross-check every redundant index against its ground truth — the
    /// fuzzer's bookkeeping oracle, cheap enough to run after every event
    /// in a fuzz run (O(components + heap entries + devices)). Verifies:
    /// frontier/meta counters vs the membership bitset, the available set's
    /// vec/bitset/per-type-count agreement, tenancy vs availability, and
    /// that every live frontier component has exactly one live entry in the
    /// rank heaps and exactly one in the deadline-or-fallback heaps, in the
    /// right bucket with bit-identical keys.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        let ncomp = self.in_frontier.len();
        let live = self.in_frontier.iter().filter(|&&f| f).count();
        if live != self.frontier_len {
            return Err(format!(
                "frontier_len {} != {} live in_frontier bits",
                self.frontier_len, live
            ));
        }
        let meta = (0..ncomp)
            .filter(|&c| self.in_frontier[c] && self.carries_meta(c))
            .count();
        if meta != self.meta_carriers {
            return Err(format!(
                "meta_carriers {} != {} frontier metadata carriers",
                self.meta_carriers, meta
            ));
        }
        let ndev = self.platform.devices.len();
        let mut per_type = [0usize; NTYPES];
        for d in 0..ndev {
            let in_vec = self.available.iter().filter(|&&x| x == d).count();
            if in_vec != usize::from(self.dev_available[d]) {
                return Err(format!(
                    "device {d}: bitset says {}, available vec holds {in_vec} entries",
                    self.dev_available[d]
                ));
            }
            if self.dev_available[d] {
                per_type[ti(self.platform.device(d).dtype)] += 1;
                if self.dev_down[d] {
                    return Err(format!("device {d} available while marked down"));
                }
                if self.platform.device(d).num_queues == 0 {
                    return Err(format!("device {d} available with no command queues"));
                }
                if self.tenants[d] >= self.tenancy {
                    return Err(format!(
                        "device {d} available at tenancy cap ({} >= {})",
                        self.tenants[d], self.tenancy
                    ));
                }
            }
        }
        if per_type != self.avail_per_type {
            return Err(format!(
                "avail_per_type {:?} != recount {:?}",
                self.avail_per_type, per_type
            ));
        }
        let mut rank_entries = vec![0usize; ncomp];
        let mut urgency_entries = vec![0usize; ncomp];
        for t in 0..NTYPES {
            for e in self.rank_heap[t].iter() {
                if !self.in_frontier[e.comp] || self.entry_seq[e.comp] != e.seq {
                    continue;
                }
                if t != ti(self.comp_pref[e.comp]) {
                    return Err(format!("comp {} rank entry in wrong bucket {t}", e.comp));
                }
                if e.rank.to_bits() != self.comp_rank[e.comp].to_bits() {
                    return Err(format!("comp {} rank entry key drifted", e.comp));
                }
                rank_entries[e.comp] += 1;
            }
            for e in self.dl_heap[t].iter() {
                if !self.in_frontier[e.comp] || self.entry_seq[e.comp] != e.seq {
                    continue;
                }
                if t != ti(self.comp_pref[e.comp]) {
                    return Err(format!("comp {} deadline entry in wrong bucket {t}", e.comp));
                }
                if e.deadline.to_bits() != self.deadline[e.comp].to_bits()
                    || !e.deadline.is_finite()
                {
                    return Err(format!("comp {} deadline entry key drifted", e.comp));
                }
                urgency_entries[e.comp] += 1;
            }
            for e in self.fb_heap[t].iter() {
                if !self.in_frontier[e.comp] || self.entry_seq[e.comp] != e.seq {
                    continue;
                }
                if t != ti(self.comp_pref[e.comp]) {
                    return Err(format!("comp {} fallback entry in wrong bucket {t}", e.comp));
                }
                if self.deadline[e.comp].is_finite()
                    || e.priority != self.priority[e.comp]
                    || e.rank.to_bits() != self.comp_rank[e.comp].to_bits()
                {
                    return Err(format!("comp {} fallback entry key drifted", e.comp));
                }
                urgency_entries[e.comp] += 1;
            }
        }
        for c in 0..ncomp {
            let want = usize::from(self.in_frontier[c]);
            if rank_entries[c] != want {
                return Err(format!(
                    "comp {c}: {} live rank entries, expected {want}",
                    rank_entries[c]
                ));
            }
            if urgency_entries[c] != want {
                return Err(format!(
                    "comp {c}: {} live urgency entries, expected {want}",
                    urgency_entries[c]
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::transformer::{cluster_by_head, transformer_dag};

    fn state_for(
        dag: &Dag,
        part: &Partition,
        platform: &Platform,
        deadline: Vec<f64>,
        priority: Vec<u32>,
    ) -> SchedState<'static> {
        // Tests leak the inputs to get a 'static state — fine for a test
        // process, and it keeps call sites free of lifetime gymnastics.
        let dag: &'static Dag = Box::leak(Box::new(dag.clone()));
        let part: &'static Partition = Box::leak(Box::new(part.clone()));
        let platform: &'static Platform = Box::leak(Box::new(platform.clone()));
        SchedState::new(dag, part, platform, &PaperCost, 1, deadline, priority).unwrap()
    }

    fn heads_app(n: usize, h_cpu: usize) -> (Dag, Partition) {
        let (dag, ios) = transformer_dag(n, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, h_cpu);
        (dag, part)
    }

    #[test]
    fn frontier_order_is_rank_desc_then_fifo() {
        let (dag, part) = heads_app(3, 0);
        let platform = Platform::paper_testbed(3, 1);
        let n = part.components.len();
        let mut st = state_for(&dag, &part, &platform, vec![f64::INFINITY; n], vec![0; n]);
        // Equal ranks (identical heads): order must be insertion order.
        st.on_ready(2);
        st.on_ready(0);
        st.on_ready(1);
        assert_eq!(st.frontier_ranked(), vec![2, 0, 1]);
        assert_eq!(st.rank_head(), Some(2));
        assert_eq!(st.frontier_len(), 3);
    }

    #[test]
    fn dispatch_and_tenancy_track_availability() {
        let (dag, part) = heads_app(2, 0);
        let platform = Platform::paper_testbed(3, 1);
        let n = part.components.len();
        let mut st = state_for(&dag, &part, &platform, vec![f64::INFINITY; n], vec![0; n]);
        st.on_ready(0);
        st.on_ready(1);
        assert!(st.has_available(DeviceType::Gpu));
        st.on_dispatch(0, 0);
        // tenancy 1: the GPU leaves the available set.
        assert!(!st.has_available(DeviceType::Gpu));
        assert!(st.has_available(DeviceType::Cpu));
        assert_eq!(st.frontier_len(), 1);
        assert_eq!(st.rank_head(), Some(1));
        st.on_complete(0);
        assert!(st.has_available(DeviceType::Gpu));
        // Available order is FIFO: CPU (never removed) first, GPU re-added.
        assert_eq!(st.available().to_vec(), vec![1, 0]);
    }

    /// A crashed device leaves the available set and stays out: tenant
    /// slots returned by its displaced residents must not resurrect it,
    /// and only an explicit `on_device_up` brings it back.
    #[test]
    fn device_down_survives_preempt_and_complete() {
        let (dag, part) = heads_app(2, 0);
        let platform = Platform::paper_testbed(3, 1);
        let n = part.components.len();
        let mut st = state_for(&dag, &part, &platform, vec![f64::INFINITY; n], vec![0; n]);
        st.on_ready(0);
        st.on_dispatch(0, 0);
        st.on_device_down(0);
        assert!(st.is_down(0));
        assert!(!st.is_available(0));
        // The displaced resident returns its tenant slot; the crashed
        // device must not re-enter the available set.
        st.on_preempt(0);
        assert_eq!(st.tenants[0], 0);
        assert!(!st.is_available(0));
        assert!(!st.has_available(DeviceType::Gpu));
        st.check_invariants().unwrap();
        // Recovery restores eligibility.
        st.on_device_up(0);
        assert!(!st.is_down(0));
        assert!(st.is_available(0));
        st.check_invariants().unwrap();
        // Down at the tenancy cap: coming back up waits for a completion.
        st.on_ready(0);
        st.on_dispatch(0, 0);
        st.on_device_down(0);
        st.on_device_up(0);
        assert!(!st.is_available(0), "still at the tenancy cap");
        st.on_complete(0);
        assert!(st.is_available(0));
        st.check_invariants().unwrap();
    }

    /// Shedding removes a frontier component without a dispatch.
    #[test]
    fn on_shed_leaves_the_frontier_clean() {
        let (dag, part) = heads_app(2, 0);
        let platform = Platform::paper_testbed(3, 1);
        let n = part.components.len();
        let mut st = state_for(&dag, &part, &platform, vec![0.5, f64::INFINITY], vec![0; n]);
        st.on_ready(0);
        st.on_ready(1);
        st.on_shed(0);
        assert_eq!(st.frontier_len(), 1);
        assert!(!st.in_frontier(0));
        assert_eq!(st.rank_head(), Some(1));
        st.on_shed(0); // no-op when absent
        assert_eq!(st.frontier_len(), 1);
        st.check_invariants().unwrap();
    }

    /// Preemption re-entry must invalidate the victim's stale heap entries:
    /// the re-entered component gets a fresh seq and (with equal ranks)
    /// moves to the back of the FIFO tier.
    #[test]
    fn preempt_reentry_skips_stale_entries() {
        let (dag, part) = heads_app(3, 0);
        let platform = Platform::paper_testbed(3, 1);
        let n = part.components.len();
        let mut st = state_for(&dag, &part, &platform, vec![f64::INFINITY; n], vec![0; n]);
        st.on_ready(0);
        st.on_ready(1);
        st.on_ready(2);
        st.on_dispatch(0, 0);
        assert_eq!(st.tenants[0], 1);
        st.on_preempt(0);
        assert_eq!(st.tenants[0], 0);
        assert!(st.has_available(DeviceType::Gpu));
        st.on_ready(0); // fresh seq: equal rank ⇒ now behind 1 and 2
        assert_eq!(st.frontier_ranked(), vec![1, 2, 0]);
        assert_eq!(st.rank_head(), Some(1));
        // The stale seq-0 entry for comp 0 must not resurface after the
        // head is consumed.
        st.on_dispatch(1, 0);
        st.on_complete(0);
        assert_eq!(st.frontier_ranked(), vec![2, 0]);
        assert_eq!(st.rank_head(), Some(2));
    }

    #[test]
    fn urgency_head_orders_by_deadline_then_static_fallback() {
        let (dag, part) = heads_app(3, 0);
        let platform = Platform::paper_testbed(3, 1);
        let n = part.components.len();
        let mut st = state_for(
            &dag,
            &part,
            &platform,
            vec![0.5, 0.2, f64::INFINITY],
            vec![0, 0, 7],
        );
        st.on_ready(0);
        st.on_ready(1);
        st.on_ready(2);
        assert_eq!(st.meta_carriers(), 3);
        // Finite deadlines beat any priority on an ∞ deadline.
        assert_eq!(st.urgency_head(false), Some(1));
        st.on_dispatch(1, 0);
        assert_eq!(st.urgency_head(false), Some(0));
        st.on_complete(0);
        st.on_dispatch(0, 0);
        // Only the ∞-deadline carrier remains.
        assert_eq!(st.urgency_head(false), Some(2));
        assert_eq!(st.meta_carriers(), 1);
    }

    /// Exact deadline ties resolve by laxity: a CPU-preferring component
    /// (slow ⇒ less slack) must come first even though the GPU component
    /// outranks it in FIFO terms.
    #[test]
    fn urgency_tie_breaks_by_laxity_across_buckets() {
        let (dag, part) = heads_app(2, 1); // head 0 on CPU, head 1 on GPU
        let platform = Platform::paper_testbed(3, 1);
        let n = part.components.len();
        let mut st = state_for(&dag, &part, &platform, vec![0.4, 0.4], vec![0; n]);
        st.on_ready(1);
        st.on_ready(0);
        assert!(st.laxity(0) < st.laxity(1), "CPU comp should have less slack");
        assert_eq!(st.urgency_head(false), Some(0));
        // Restricted to available types: with the CPU bucket masked out the
        // GPU component is the most urgent placeable one.
        while let Some(d) = st.first_available_of(DeviceType::Cpu) {
            st.mark_unavailable(d);
        }
        assert_eq!(st.urgency_head(true), Some(1));
    }

    #[test]
    fn urgency_head_consumes_nothing() {
        let (dag, part) = heads_app(2, 0);
        let platform = Platform::paper_testbed(3, 1);
        let mut st = state_for(&dag, &part, &platform, vec![0.3, 0.3], vec![0, 0]);
        st.on_ready(0);
        st.on_ready(1);
        let first = st.urgency_head(false);
        let second = st.urgency_head(false);
        assert_eq!(first, second, "urgency peek must be idempotent");
        assert_eq!(st.frontier_len(), 2);
    }

    fn slot_state(platform: &Platform, tenancy: usize) -> SchedState<'static> {
        let dag: &'static Dag = Box::leak(Box::new(Dag::default()));
        let part: &'static Partition = Box::leak(Box::new(Partition {
            components: Vec::new(),
            assignment: Vec::new(),
        }));
        let platform: &'static Platform = Box::leak(Box::new(platform.clone()));
        SchedState::for_streaming(dag, part, platform, &PaperCost, tenancy).unwrap()
    }

    /// Slot mode must reproduce the rebuilt state bit for bit: same
    /// `component_time` on every device, same laxity, same selection heads.
    #[test]
    fn slot_mode_matches_rebuilt_state() {
        let (dag, part) = heads_app(2, 1); // head 0 on CPU, head 1 on GPU
        let platform = Platform::paper_testbed(3, 1);
        let n = part.components.len();
        let deadline = vec![0.4, 0.4];
        let priority = vec![0u32, 3];
        let mut reference = state_for(&dag, &part, &platform, deadline.clone(), priority.clone());

        let ranks = crate::sched::component_ranks(&dag, &part, &platform, &PaperCost);
        let mut st = slot_state(&platform, 1);
        for c in 0..n {
            let dev_times: Vec<f64> = platform
                .devices
                .iter()
                .map(|d| {
                    part.components[c]
                        .kernels
                        .iter()
                        .map(|&k| PaperCost.exec_time(&dag.kernels[k], d))
                        .sum()
                })
                .collect();
            st.set_slot(c, ranks[c], part.components[c].dev, deadline[c], priority[c], &dev_times);
        }
        for c in 0..n {
            for d in &platform.devices {
                assert_eq!(
                    st.component_time(c, d).to_bits(),
                    reference.component_time(c, d).to_bits(),
                    "slot table must be bit-identical to the DAG walk"
                );
            }
            assert_eq!(st.laxity(c).to_bits(), reference.laxity(c).to_bits());
            assert_eq!(st.rank(c).to_bits(), reference.rank(c).to_bits());
            assert_eq!(st.pref(c), reference.pref(c));
        }
        reference.on_ready(0);
        reference.on_ready(1);
        st.on_ready(0);
        st.on_ready(1);
        assert_eq!(st.urgency_head(false), reference.urgency_head(false));
        assert_eq!(st.rank_head(), reference.rank_head());
        assert_eq!(st.frontier_ranked(), reference.frontier_ranked());
    }

    /// Retired slots are rebound in place: per-slot vectors stay at the
    /// peak live count and the new metadata fully replaces the old.
    #[test]
    fn slot_reuse_overwrites_retired_metadata() {
        let platform = Platform::paper_testbed(3, 1);
        let ndev = platform.devices.len();
        let mut st = slot_state(&platform, 4);
        st.set_slot(0, 5.0, DeviceType::Gpu, f64::INFINITY, 0, &vec![1.0; ndev]);
        st.on_ready(0);
        st.on_dispatch(0, 0);
        st.on_complete(0); // slot 0 retired
        st.set_slot(0, 2.0, DeviceType::Cpu, 0.5, 9, &vec![0.25; ndev]);
        assert_eq!(st.rank(0), 2.0);
        assert_eq!(st.pref(0), DeviceType::Cpu);
        assert_eq!(st.priority[0], 9);
        st.now = 0.1;
        assert!((st.laxity(0) - (0.5 - 0.1 - 0.25)).abs() < 1e-12);
        st.on_ready(0);
        assert_eq!(st.frontier_len(), 1);
        assert_eq!(st.urgency_head(false), Some(0));
    }

    /// Heap compaction drops only stale entries: pop order of live ones is
    /// unchanged, and the entry count collapses back to the live frontier.
    #[test]
    fn compact_heaps_is_behavior_neutral() {
        let platform = Platform::paper_testbed(3, 1);
        let ndev = platform.devices.len();
        let mut st = slot_state(&platform, 4);
        for s in 0..8 {
            st.set_slot(s, 1.0 + s as f64, DeviceType::Gpu, f64::INFINITY, 0, &vec![1.0; ndev]);
            st.on_ready(s);
        }
        // Churn slots 0..6 through dispatch/complete/rebind: the heaps keep
        // their stale entries (lazy deletion).
        for s in 0..6 {
            st.on_dispatch(s, 0);
            st.on_complete(s);
            st.set_slot(s, 0.5, DeviceType::Gpu, f64::INFINITY, 0, &vec![1.0; ndev]);
            st.on_ready(s);
        }
        assert!(st.heap_entries() > st.frontier_len());
        let before = st.frontier_ranked();
        st.compact_heaps();
        assert_eq!(st.heap_entries(), st.frontier_len());
        assert_eq!(st.frontier_ranked(), before);
        assert_eq!(st.rank_head(), Some(7), "highest-rank live slot survives");
    }

    /// The choice lists must surface every bitwise-tied head candidate in
    /// FIFO order without consuming the frontier.
    #[test]
    fn choice_lists_expose_ties_in_fifo_order() {
        let platform = Platform::paper_testbed(3, 1);
        let ndev = platform.devices.len();
        let mut st = slot_state(&platform, 4);
        // Three rank-tied slots (two GPU, one CPU) and one strictly lower.
        st.set_slot(0, 3.0, DeviceType::Gpu, f64::INFINITY, 0, &vec![1.0; ndev]);
        st.set_slot(1, 3.0, DeviceType::Cpu, f64::INFINITY, 0, &vec![1.0; ndev]);
        st.set_slot(2, 3.0, DeviceType::Gpu, f64::INFINITY, 0, &vec![1.0; ndev]);
        st.set_slot(3, 1.0, DeviceType::Gpu, f64::INFINITY, 0, &vec![1.0; ndev]);
        st.on_ready(2);
        st.on_ready(0);
        st.on_ready(1);
        st.on_ready(3);
        assert_eq!(st.rank_head_ties(), vec![2, 0, 1], "entry order, cross-bucket");
        assert_eq!(st.rank_head_ties(), vec![2, 0, 1], "peek must be idempotent");
        assert_eq!(st.frontier_len(), 4);
        assert_eq!(st.rank_head(), Some(2));
        st.check_invariants().unwrap();
    }

    #[test]
    fn urgency_ties_cover_deadline_and_fallback_heads() {
        let platform = Platform::paper_testbed(3, 1);
        let ndev = platform.devices.len();
        let mut st = slot_state(&platform, 4);
        // Bitwise-equal deadlines on slots 1 and 2; slot 0 deadline-free.
        st.set_slot(0, 2.0, DeviceType::Gpu, f64::INFINITY, 5, &vec![1.0; ndev]);
        st.set_slot(1, 2.0, DeviceType::Gpu, 0.75, 0, &vec![1.0; ndev]);
        st.set_slot(2, 2.0, DeviceType::Cpu, 0.75, 0, &vec![1.0; ndev]);
        st.on_ready(0);
        st.on_ready(1);
        st.on_ready(2);
        assert_eq!(st.urgency_head_ties(false), vec![1, 2]);
        assert_eq!(st.frontier_len(), 3, "choice list must not consume");
        st.on_dispatch(1, 0);
        st.on_dispatch(2, 1);
        // Only the fallback head remains in scope.
        assert_eq!(st.urgency_head_ties(false), vec![0]);
        assert_eq!(st.entry_seq_of(0), Some(0));
        assert_eq!(st.entry_seq_of(1), None);
        st.check_invariants().unwrap();
    }

    #[test]
    fn check_invariants_accepts_event_api_states() {
        let (dag, part) = heads_app(3, 1);
        let platform = Platform::paper_testbed(3, 1);
        let n = part.components.len();
        let mut st = state_for(&dag, &part, &platform, vec![0.5, 0.2, f64::INFINITY], vec![0; n]);
        st.check_invariants().unwrap();
        st.on_ready(0);
        st.on_ready(1);
        st.on_ready(2);
        st.check_invariants().unwrap();
        st.on_dispatch(1, 0);
        st.check_invariants().unwrap();
        st.on_preempt(0);
        st.on_ready(1);
        st.check_invariants().unwrap();
        st.on_dispatch(0, 0);
        st.on_complete(0);
        st.check_invariants().unwrap();
    }
}
