//! **Reference view-based scheduler API** — the pre-PR-5 `Policy` trait
//! and policies, kept callable so the indexed [`super::SchedState`] world
//! can be proven equivalent against them (mirroring the
//! [`crate::sim::reference`] engine pattern).
//!
//! Here `select` receives a freshly materialized [`SchedView`] and scans
//! the whole frontier — O(F) per decision (plus an O(F) laxity-tie
//! hashmap for `edf`). Do **not** use these outside equivalence/property
//! tests ([`crate::sim::reference`] builds these views) or the
//! before/after rows of `benches/serve_overload.rs` /
//! `benches/serve_scale.rs`; production paths run the indexed policies in
//! [`super::policy`].

use super::ResidentTenant;
use crate::cost::CostModel;
use crate::graph::{Dag, Partition};
use crate::platform::{Device, DeviceId, Platform};

/// Read-only scheduler state offered to the reference `select` (Algorithm
/// 1 line 5): the frontier `F` (rank-sorted, descending), the
/// available-device set `A`, and auxiliary estimates for EFT-style
/// policies.
pub struct SchedView<'a> {
    pub now: f64,
    /// Ready component ids, sorted by bottom-level rank, best first.
    pub frontier: &'a [usize],
    /// Available (idle) devices.
    pub available: &'a [DeviceId],
    pub platform: &'a Platform,
    pub partition: &'a Partition,
    pub dag: &'a Dag,
    /// Estimated time each device becomes free (≤ now when idle).
    pub est_free: &'a [f64],
    /// Cross-DAG busyness signal per device: 0 when idle, growing as the
    /// device takes on work. Policies should compare devices *relatively*
    /// (less vs more loaded), not against absolute thresholds.
    pub device_load: &'a [f64],
    /// Absolute deadline per component, seconds since the serving epoch
    /// (`f64::INFINITY` when the request carries none).
    pub deadline: &'a [f64],
    /// Request priority per component (larger = more urgent; 0 default).
    pub priority: &'a [u32],
    pub cost: &'a dyn CostModel,
}

impl<'a> SchedView<'a> {
    /// Solo execution-time estimate of an entire component on a device.
    pub fn component_time(&self, comp: usize, dev: &Device) -> f64 {
        self.partition.components[comp]
            .kernels
            .iter()
            .map(|&k| self.cost.exec_time(&self.dag.kernels[k], dev))
            .sum()
    }

    /// Laxity of `comp`: slack between its absolute deadline and its
    /// estimated completion were it dispatched *now* on a device of its
    /// preferred type (+∞ for deadline-free components). Negative laxity
    /// means the deadline is already unmeetable under the solo estimate.
    pub fn laxity(&self, comp: usize) -> f64 {
        if self.deadline[comp].is_infinite() {
            return f64::INFINITY;
        }
        let want = self.partition.components[comp].dev;
        let dev = self
            .platform
            .devices
            .iter()
            .find(|d| d.dtype == want)
            .or_else(|| self.platform.devices.first());
        match dev {
            Some(d) => self.deadline[comp] - self.now - self.component_time(comp, d),
            None => f64::INFINITY,
        }
    }
}

/// The reference (view-based) `select` routine: choose a ready component
/// and a device, or `None` to block until a callback updates `F`/`A`.
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)>;

    /// Command queues this policy sets up on `device`.
    fn queues_for(&self, device: &Device) -> usize {
        device.num_queues
    }

    /// See [`super::Policy::can_preempt`].
    fn can_preempt(&self) -> bool {
        false
    }

    /// See [`super::Policy::preempt`].
    fn preempt(&mut self, _view: &SchedView, _resident: &[ResidentTenant]) -> Option<usize> {
        None
    }
}

/// Reference *clustering*: O(F) scan for the highest-ranked component
/// whose device preference matches an available device.
#[derive(Debug, Default)]
pub struct Clustering;

impl Policy for Clustering {
    fn name(&self) -> &'static str {
        "clustering"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        for &comp in view.frontier {
            let want = view.partition.components[comp].dev;
            if let Some(&dev) = view
                .available
                .iter()
                .find(|&&d| view.platform.device(d).dtype == want)
            {
                return Some((comp, dev));
            }
        }
        None
    }
}

/// Reference *eager*: highest-ranked component onto any available device.
#[derive(Debug, Default)]
pub struct Eager;

impl Policy for Eager {
    fn name(&self) -> &'static str {
        "eager"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        let comp = *view.frontier.first()?;
        let dev = *view.available.first()?;
        Some((comp, dev))
    }

    fn queues_for(&self, _device: &Device) -> usize {
        1
    }
}

/// Reference *HEFT*: highest-ranked component onto the earliest-finishing
/// device, blocking while the EFT-optimal device is busy.
#[derive(Debug, Default)]
pub struct Heft;

impl Policy for Heft {
    fn name(&self) -> &'static str {
        "heft"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        let comp = *view.frontier.first()?;
        // argmin over ALL devices of EFT = max(now, est_free) + exec.
        let mut best: Option<(DeviceId, f64)> = None;
        for d in &view.platform.devices {
            if d.num_queues == 0 {
                continue;
            }
            let eft = view.est_free[d.id].max(view.now) + view.component_time(comp, d);
            if best.map(|(_, t)| eft < t).unwrap_or(true) {
                best = Some((d.id, eft));
            }
        }
        let (dev, _) = best?;
        if view.available.contains(&dev) {
            Some((comp, dev))
        } else {
            None
        }
    }

    fn queues_for(&self, _device: &Device) -> usize {
        1
    }
}

/// Reference *least-loaded*: preference-honouring, least cross-DAG
/// occupancy among matching available devices (O(F) frontier scan).
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Policy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        for &comp in view.frontier {
            let want = view.partition.components[comp].dev;
            let best = view
                .available
                .iter()
                .copied()
                .filter(|&d| view.platform.device(d).dtype == want)
                .min_by(|&a, &b| {
                    view.device_load[a]
                        .total_cmp(&view.device_load[b])
                        .then_with(|| view.est_free[a].total_cmp(&view.est_free[b]))
                });
            if let Some(dev) = best {
                return Some((comp, dev));
            }
        }
        None
    }
}

/// Reference *EDF*: earliest-absolute-deadline first with laxity
/// tie-break, rank fallback, and strict-dominance preemption. Re-derives
/// the urgency order per call: an O(F) laxity-tie hashmap, an O(F) head
/// scan, and a full O(F log F) sort on blocked-but-placeable rounds.
#[derive(Debug, Default)]
pub struct Edf;

impl Edf {
    /// The one urgency comparator behind `select` ordering, the blocked
    /// head-of-line scan, AND preemption dominance: deadline ascending,
    /// laxity ascending on exact deadline ties, then priority descending.
    fn cmp_with(view: &SchedView, a: usize, la: f64, b: usize, lb: f64) -> std::cmp::Ordering {
        view.deadline[a]
            .total_cmp(&view.deadline[b])
            .then_with(|| la.total_cmp(&lb))
            .then_with(|| view.priority[b].cmp(&view.priority[a]))
    }

    /// Laxity per frontier candidate, computed only where the comparator
    /// can reach it — on finite deadlines shared by another candidate.
    fn tied_laxities(view: &SchedView) -> Vec<(usize, f64)> {
        let mut counts: std::collections::HashMap<u64, u32> =
            std::collections::HashMap::with_capacity(view.frontier.len());
        for &c in view.frontier {
            if view.deadline[c].is_finite() {
                *counts.entry(view.deadline[c].to_bits()).or_insert(0) += 1;
            }
        }
        view.frontier
            .iter()
            .map(|&c| {
                let d = view.deadline[c];
                let tied = d.is_finite() && counts.get(&d.to_bits()).is_some_and(|&n| n > 1);
                (c, if tied { view.laxity(c) } else { f64::INFINITY })
            })
            .collect()
    }

    /// Lazy pairwise form of [`Edf::cmp_with`]: laxity is only computed on
    /// exact deadline ties (`then_with` short-circuits).
    fn urgency_cmp(view: &SchedView, a: usize, b: usize) -> std::cmp::Ordering {
        view.deadline[a]
            .total_cmp(&view.deadline[b])
            .then_with(|| view.laxity(a).total_cmp(&view.laxity(b)))
            .then_with(|| view.priority[b].cmp(&view.priority[a]))
    }

    /// Strict urgency dominance in the select order.
    fn more_urgent(view: &SchedView, a: usize, b: usize) -> bool {
        Edf::urgency_cmp(view, a, b).is_lt()
    }

    /// Least-loaded available device matching `comp`'s type preference.
    fn best_device(view: &SchedView, comp: usize) -> Option<DeviceId> {
        let want = view.partition.components[comp].dev;
        view.available
            .iter()
            .copied()
            .filter(|&d| view.platform.device(d).dtype == want)
            .min_by(|&a, &b| {
                view.device_load[a]
                    .total_cmp(&view.device_load[b])
                    .then_with(|| view.est_free[a].total_cmp(&view.est_free[b]))
            })
    }

    /// Head-of-line blocked candidate: the urgency-order minimum restricted
    /// to components carrying urgency metadata.
    fn most_urgent_candidate(view: &SchedView) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (c, lax) in Edf::tied_laxities(view) {
            if !(view.deadline[c].is_finite() || view.priority[c] > 0) {
                continue;
            }
            let better = match best {
                None => true,
                Some((b, bl)) => Edf::cmp_with(view, c, lax, b, bl).is_lt(),
            };
            if better {
                best = Some((c, lax));
            }
        }
        best.map(|(c, _)| c)
    }
}

impl Policy for Edf {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn select(&mut self, view: &SchedView) -> Option<(usize, DeviceId)> {
        // With no urgency metadata anywhere the order degenerates to the
        // frontier's native rank order.
        if view
            .frontier
            .iter()
            .all(|&c| view.deadline[c].is_infinite() && view.priority[c] == 0)
        {
            return view
                .frontier
                .iter()
                .find_map(|&c| Edf::best_device(view, c).map(|d| (c, d)));
        }
        // Common dispatch path, sort-free: the urgency-order head is
        // usually placeable.
        let cands = Edf::tied_laxities(view);
        let head = cands
            .iter()
            .copied()
            .min_by(|&(a, la), &(b, lb)| Edf::cmp_with(view, a, la, b, lb))
            .map(|(c, _)| c)?;
        if let Some(dev) = Edf::best_device(view, head) {
            return Some((head, dev));
        }
        // Head unplaceable. Fully-blocked rounds exit without sorting; the
        // full sort only runs when some *other* candidate can be placed.
        if !view
            .frontier
            .iter()
            .any(|&c| Edf::best_device(view, c).is_some())
        {
            return None;
        }
        let mut order = cands;
        order.sort_by(|&(a, la), &(b, lb)| Edf::cmp_with(view, a, la, b, lb));
        for (comp, _) in order {
            if comp == head {
                continue;
            }
            if let Some(dev) = Edf::best_device(view, comp) {
                return Some((comp, dev));
            }
        }
        None
    }

    fn can_preempt(&self) -> bool {
        true
    }

    fn preempt(&mut self, view: &SchedView, resident: &[ResidentTenant]) -> Option<usize> {
        let urgent = Edf::most_urgent_candidate(view)?;
        let want = view.partition.components[urgent].dev;
        resident
            .iter()
            .filter(|r| view.platform.device(r.device).dtype == want)
            .filter(|r| {
                Edf::more_urgent(view, urgent, r.comp)
                    && (view.deadline[urgent] < view.deadline[r.comp]
                        || view.priority[urgent] > view.priority[r.comp])
            })
            // Least urgent victim = maximum in the shared urgency order.
            .max_by(|a, b| Edf::urgency_cmp(view, a.comp, b.comp))
            .map(|r| r.comp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::platform::DeviceType;
    use crate::transformer::{cluster_by_head, transformer_dag};

    /// Neutral serving metadata: no deadlines, default priority.
    fn no_meta(ncomp: usize) -> (Vec<f64>, Vec<u32>) {
        (vec![f64::INFINITY; ncomp], vec![0u32; ncomp])
    }

    #[allow(clippy::too_many_arguments)]
    fn view_meta<'a>(
        dag: &'a Dag,
        part: &'a Partition,
        platform: &'a Platform,
        frontier: &'a [usize],
        available: &'a [DeviceId],
        est_free: &'a [f64],
        device_load: &'a [f64],
        deadline: &'a [f64],
        priority: &'a [u32],
    ) -> SchedView<'a> {
        SchedView {
            now: 0.0,
            frontier,
            available,
            platform,
            partition: part,
            dag,
            est_free,
            device_load,
            deadline,
            priority,
            cost: &PaperCost,
        }
    }

    /// The reference policies' semantics are what the equivalence suite
    /// pins the indexed policies against — keep a behavioural anchor for
    /// each family here.
    #[test]
    fn reference_policies_keep_their_selection_rules() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 1); // head 0 on CPU
        let platform = Platform::paper_testbed(2, 1);
        let frontier = [0usize, 1];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        let (dl, pr) = no_meta(2);
        // Clustering honours the preference.
        let v = view_meta(&dag, &part, &platform, &frontier, &[1], &est, &load, &dl, &pr);
        assert_eq!(Clustering.select(&v), Some((0, 1)));
        let v = view_meta(&dag, &part, &platform, &frontier, &[0], &est, &load, &dl, &pr);
        assert_eq!(Clustering.select(&v), Some((1, 0)));
        // Eager ignores it.
        let v = view_meta(&dag, &part, &platform, &frontier, &[1], &est, &load, &dl, &pr);
        assert_eq!(Eager.select(&v), Some((0, 1)));
        // Blocked frontier.
        let v = view_meta(&dag, &part, &platform, &frontier, &[], &est, &load, &dl, &pr);
        assert_eq!(Clustering.select(&v), None);
    }

    #[test]
    fn reference_edf_orders_by_deadline_and_preempts_strictly() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 1);
        let frontier = [0usize, 1];
        let est = [0.0, 0.0];
        let load = [0.0, 0.0];
        let dl = [0.5, 0.2];
        let pr = [0u32, 0];
        let v = view_meta(&dag, &part, &platform, &frontier, &[0], &est, &load, &dl, &pr);
        assert_eq!(Edf.select(&v), Some((1, 0)));
        // Preemption: strict dominance only.
        let blocked = [1usize];
        let resident = [ResidentTenant { comp: 0, device: 0 }];
        let dl = [f64::INFINITY, 0.1];
        let v = view_meta(&dag, &part, &platform, &blocked, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&v, &resident), Some(0));
        let dl = [0.1, 0.1];
        let v = view_meta(&dag, &part, &platform, &blocked, &[], &est, &load, &dl, &pr);
        assert_eq!(Edf.preempt(&v, &resident), None);
    }
}
