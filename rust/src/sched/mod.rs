//! Scheduling (paper §4B Algorithm 1 + §5 policies).
//!
//! The scheduling *loop* (frontier `F`, device set `A`, select → setup_cq →
//! dispatch → callbacks) lives in the execution engines ([`crate::sim`] for
//! the modeled platform, [`crate::exec`] for real PJRT execution); this
//! module defines the pluggable pieces:
//!
//! * [`Policy`] — the paper's overridable `select` routine.
//! * [`Clustering`] — static fine-grained scheme (Expt 1): components are
//!   dispatched to devices matching their preference, ordered by bottom-level
//!   rank.
//! * [`Eager`] — StarPU-inspired dynamic scheme (Expt 2): singleton
//!   components, one queue per device, any available device.
//! * [`Heft`] — HEFT (Expt 3): singleton components, earliest-finish-time
//!   device choice using profiled execution times.
//! * [`LeastLoaded`] — serving policy: preference-honouring like clustering,
//!   but spreads concurrent requests across matching devices by the
//!   cross-DAG occupancy the multi-tenant [`SchedView`] exposes.
//! * [`Edf`] — deadline-aware serving policy: earliest absolute deadline
//!   first (laxity tie-break, rank fallback), with a preemption rule that
//!   displaces strictly less urgent resident tenants via
//!   [`Policy::preempt`].

pub mod autotune;
pub mod policy;
pub mod ranks;

pub use autotune::{exhaustive, hill_climb, TuneResult, TuneSpace};
pub use policy::{
    app_solo_estimate, Clustering, Eager, Edf, Heft, LeastLoaded, Policy, ResidentTenant, SchedView,
};
pub use ranks::component_ranks;
