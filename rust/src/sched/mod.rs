//! Scheduling (paper §4B Algorithm 1 + §5 policies).
//!
//! The scheduling *loop* (frontier `F`, device set `A`, select → setup_cq →
//! dispatch → callbacks) lives in the execution engines ([`crate::sim`] for
//! the modeled platform, [`crate::exec`] for real PJRT execution); this
//! module defines the pluggable pieces:
//!
//! * [`SchedState`] — the **incrementally maintained scheduler core**
//!   (PR 5): per-device-type frontier buckets, a deadline-keyed urgency
//!   heap, a rank-keyed heap, and cached device load/tenancy counters, all
//!   updated by narrow events (`on_ready`/`on_dispatch`/`on_complete`/
//!   `on_preempt`) instead of reconstructed per decision. Both engines
//!   drive one `SchedState`, so sim and real share a single scheduler
//!   core, and every shipped policy's `select` is O(log frontier).
//! * [`Policy`] — the paper's overridable `select` routine, redesigned
//!   around the indexed state.
//! * [`Clustering`] — static fine-grained scheme (Expt 1): components are
//!   dispatched to devices matching their preference, ordered by bottom-level
//!   rank.
//! * [`Eager`] — StarPU-inspired dynamic scheme (Expt 2): singleton
//!   components, one queue per device, any available device.
//! * [`Heft`] — HEFT (Expt 3): singleton components, earliest-finish-time
//!   device choice using profiled execution times.
//! * [`LeastLoaded`] — serving policy: preference-honouring like clustering,
//!   but spreads concurrent requests across matching devices by the
//!   cross-DAG occupancy the multi-tenant state exposes.
//! * [`Edf`] — deadline-aware serving policy: earliest absolute deadline
//!   first (laxity tie-break, rank fallback), with a preemption rule that
//!   displaces strictly less urgent resident tenants via
//!   [`Policy::preempt`].
//!
//! The pre-PR-5 view-based trait and policies are preserved verbatim in
//! [`reference`] (doc-hidden), proven decision- and bit-identical by the
//! `prop_policy_equiv` and `integration_sim_equiv` suites.

pub mod autotune;
pub mod fuzz;
pub mod policy;
pub mod ranks;
#[doc(hidden)]
pub mod reference;
pub mod state;

pub use autotune::{exhaustive, hill_climb, TuneResult, TuneSpace};
pub use policy::{
    app_solo_estimate, Clustering, Eager, Edf, Heft, LeastLoaded, Policy, ResidentTenant,
};
pub use ranks::component_ranks;
pub use state::SchedState;
