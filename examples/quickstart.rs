//! Quickstart: the paper's Fig. 2 example (vadd → vsin) through the whole
//! stack — build a DAG with the library API, simulate it on the modeled
//! GTX-970 + i5 testbed, then execute it for real on the PJRT CPU client
//! and check the numerics.
//!
//! Run: `cargo run --release --example quickstart`

use pyschedcl::cost::PaperCost;
use pyschedcl::exec::execute_dag;
use pyschedcl::graph::Partition;
use pyschedcl::platform::Platform;
use pyschedcl::runtime::{manifest::default_artifact_dir, Runtime};
use pyschedcl::sched::Clustering;
use pyschedcl::sim::{simulate, SimConfig};
use pyschedcl::transformer::vadd_vsin_dag;
use std::collections::HashMap;
use std::sync::Arc;

fn main() -> pyschedcl::Result<()> {
    // 1. The application DAG: k0 = vadd(b0, b1) -> b2; k1 = vsin(b3 in-place)
    //    with the buffer edge (b2, b3) — exactly Fig. 2.
    let n = 4096u64;
    let (dag, kernels) = vadd_vsin_dag(n);
    let partition = Partition::singletons(&dag);
    println!(
        "DAG: {} kernels, {} buffers, {} edge(s)",
        dag.num_kernels(),
        dag.buffers.len(),
        dag.buffer_edges.len()
    );

    // 2. Simulate on the paper's testbed (2 GPU queues, 1 CPU queue).
    let platform = Platform::paper_testbed(2, 1);
    let sim = simulate(
        &dag,
        &partition,
        &platform,
        &PaperCost,
        &mut Clustering,
        &SimConfig::default(),
    )?;
    println!("simulated makespan: {:.3} ms", sim.makespan * 1e3);

    // 3. Execute for real: kernels are AOT-compiled Pallas programs loaded
    //    via PJRT. Python is NOT involved here.
    let runtime = Arc::new(Runtime::new(&default_artifact_dir())?);
    println!("pjrt platform: {}", runtime.platform_name());
    let a: Vec<f32> = (0..n).map(|i| (i as f32) * 1e-3).collect();
    let b: Vec<f32> = (0..n).map(|i| 1.0 - (i as f32) * 5e-4).collect();
    let mut inputs = HashMap::new();
    inputs.insert(dag.kernels[kernels[0]].inputs[0], a.clone());
    inputs.insert(dag.kernels[kernels[0]].inputs[1], b.clone());
    let report = execute_dag(
        &dag,
        &partition,
        &platform,
        &PaperCost,
        &mut Clustering,
        &runtime,
        &inputs,
    )?;
    println!("real makespan: {:.3} ms (wall)", report.makespan * 1e3);

    // 4. Verify: out[i] == sin(a[i] + b[i]).
    let out_buf = dag.kernels[kernels[1]].outputs[0];
    let out = report.store.host(out_buf).expect("output read back");
    let mut max_err = 0f32;
    for i in 0..n as usize {
        let want = (a[i] + b[i]).sin();
        max_err = max_err.max((out[i] - want).abs());
    }
    println!("numerics: max |err| = {max_err:.2e} over {n} elements");
    assert!(max_err < 1e-5, "verification failed");
    println!("quickstart OK");
    Ok(())
}
