//! Polybench pipelines (the paper's kernel source suite) scheduled and
//! executed for real: 2mm, 3mm, atax, bicg, mvt at β=64, each run under
//! clustering on the PJRT CPU client, with a scheduling-policy comparison
//! in the simulator.
//!
//! Run: `cargo run --release --example polybench_pipelines`

use pyschedcl::cost::PaperCost;
use pyschedcl::exec::execute_dag;
use pyschedcl::graph::{Dag, Partition};
use pyschedcl::platform::{DeviceType, Platform};
use pyschedcl::runtime::{manifest::default_artifact_dir, Runtime};
use pyschedcl::sched::{Clustering, Heft};
use pyschedcl::sim::{simulate, SimConfig};
use pyschedcl::transformer::polybench;
use std::collections::HashMap;
use std::sync::Arc;

fn rng_vec(seed: u64, len: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
    (0..len)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect()
}

fn main() -> pyschedcl::Result<()> {
    let beta = 64u64;
    let runtime = Arc::new(Runtime::new(&default_artifact_dir())?);
    let platform = Platform::paper_testbed(2, 1);
    let cfg = SimConfig::default();

    let benchmarks: Vec<(&str, (Dag, Vec<usize>))> = vec![
        ("2mm", polybench::mm2_dag(beta, DeviceType::Gpu)),
        ("3mm", polybench::mm3_dag(beta, DeviceType::Gpu)),
        ("atax", polybench::atax_dag(beta, DeviceType::Gpu)),
        ("bicg", polybench::bicg_dag(beta, DeviceType::Gpu)),
        ("mvt", polybench::mvt_dag(beta, DeviceType::Gpu)),
    ];

    println!("Polybench pipelines at β={beta} (sim: clustering vs heft; real: PJRT)\n");
    println!("bench | kernels | sim clustering | sim heft | real wall | output checksum");
    println!("------+---------+----------------+----------+-----------+----------------");
    for (name, (dag, _ks)) in &benchmarks {
        // Whole pipeline as one GPU component (clustering) vs singletons.
        let all: Vec<usize> = (0..dag.num_kernels()).collect();
        let clustered = Partition::new(dag, vec![(all, DeviceType::Gpu)])?;
        let singles = Partition::singletons(dag);
        let cl = simulate(dag, &clustered, &platform, &PaperCost, &mut Clustering, &cfg)?;
        let p1 = Platform::paper_testbed(1, 1);
        let hf = simulate(dag, &singles, &p1, &PaperCost, &mut Heft, &cfg)?;

        // Real execution: seed every isolated input.
        let mut inputs = HashMap::new();
        for b in &dag.buffers {
            let is_input = dag.kernels[b.kernel].inputs.contains(&b.id);
            if is_input && dag.buffer_pred(b.id).is_none() {
                inputs.insert(b.id, rng_vec(b.id as u64 + 1, (b.size_bytes / 4) as usize));
            }
        }
        let report = execute_dag(
            dag,
            &clustered,
            &platform,
            &PaperCost,
            &mut Clustering,
            &runtime,
            &inputs,
        )?;
        let checksum: f32 = dag
            .sink_kernels()
            .iter()
            .flat_map(|&k| dag.kernels[k].outputs.clone())
            .filter_map(|b| report.store.host(b))
            .map(|v| v.iter().sum::<f32>())
            .sum();
        println!(
            "{name:<5} | {:>7} | {:>12.2}ms | {:>6.2}ms | {:>7.2}ms | {checksum:>14.4}",
            dag.num_kernels(),
            cl.makespan * 1e3,
            hf.makespan * 1e3,
            report.makespan * 1e3
        );
    }
    println!("\npolybench_pipelines OK");
    Ok(())
}
