//! Compare the paper's three scheduling policies on the simulated testbed
//! and regenerate the §5 tables at reduced scale.
//!
//! Run: `cargo run --release --example scheduling_policies`

use pyschedcl::report::experiments::{
    expt1, expt2, expt3, format_baseline, format_expt1, motivation,
};

fn main() -> pyschedcl::Result<()> {
    println!("== Figs. 4/5: coarse vs fine-grained (1 head, β=256) ==");
    let m = motivation(256)?;
    println!(
        "coarse {:.1} ms -> fine {:.1} ms  (speedup {:.3}x; paper: 105 -> 95 ms)\n",
        m.coarse_ms, m.fine_ms, m.speedup
    );

    println!("== Expt 1 (Fig. 11) ==");
    print!("{}", format_expt1(&expt1(16, 256, 1)?));

    println!("\n== Expt 2 (Fig. 12a) ==");
    print!("{}", format_baseline(&expt2(16, &[64, 128, 256, 512])?, "eager"));

    println!("\n== Expt 3 (Fig. 12b) ==");
    print!("{}", format_baseline(&expt3(16, &[64, 128, 256, 512])?, "heft"));
    Ok(())
}
