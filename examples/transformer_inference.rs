//! End-to-end validation driver (DESIGN.md E-E2E): serve batched
//! transformer-layer inference requests through the full three-layer stack.
//!
//! * L1/L2: the per-kernel GEMM/softmax/transpose Pallas programs were AOT
//!   compiled by `make artifacts`.
//! * L3: this binary loads them via PJRT, schedules the H-head layer DAG
//!   with the paper's clustering policy, and serves a batch of requests,
//!   reporting latency percentiles and throughput.
//!
//! Correctness is cross-checked request-by-request against the *fused*
//! attention-head artifact (`head_b{β}`) — the DAG-composed execution and
//! the single fused XLA program must agree.
//!
//! Run: `cargo run --release --example transformer_inference -- [requests] [heads] [beta]`

use pyschedcl::cost::PaperCost;
use pyschedcl::exec::execute_dag;
use pyschedcl::platform::{DeviceType, Platform};
use pyschedcl::runtime::{manifest::default_artifact_dir, Runtime};
use pyschedcl::sched::Clustering;
use pyschedcl::transformer::{cluster_by_head, transformer_dag};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

fn rng_vec(seed: u64, len: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
    (0..len)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect()
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

fn main() -> pyschedcl::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(32);
    let heads: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let beta: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);

    println!("== PySchedCL transformer inference (real PJRT execution) ==");
    println!("requests={requests} heads={heads} beta={beta}");

    // Build-time artifacts -> runtime executables (off the request path).
    let runtime = Arc::new(Runtime::new(&default_artifact_dir())?);
    let t0 = Instant::now();
    let warmed = runtime.warmup()?;
    println!(
        "warmup: {warmed} executables compiled in {:.2}s (platform {})",
        t0.elapsed().as_secs_f64(),
        runtime.platform_name()
    );

    // The H-head layer DAG, heads clustered one component each (the paper's
    // clustering partition), all on the "GPU" worker pool.
    let (dag, ios) = transformer_dag(heads, beta, DeviceType::Gpu);
    let partition = cluster_by_head(&dag, &ios, 0);
    let platform = Platform::paper_testbed(3, 1);
    println!(
        "layer DAG: {} kernels / {} buffers / {} components",
        dag.num_kernels(),
        dag.buffers.len(),
        partition.components.len()
    );

    let n = (beta * beta) as usize;
    let mut latencies = Vec::with_capacity(requests);
    let mut max_err_overall = 0f32;
    let served_t0 = Instant::now();
    for req in 0..requests {
        // Fresh input sentence matrix X per request; per-head weights fixed.
        let x = rng_vec(1000 + req as u64, n);
        let mut inputs: HashMap<usize, Vec<f32>> = HashMap::new();
        let mut head_weights = Vec::new();
        for (h, io) in ios.iter().enumerate() {
            for &xb in &io.x_inputs {
                inputs.insert(xb, x.clone());
            }
            let ws: Vec<Vec<f32>> = (0..4)
                .map(|w| rng_vec(77 + (h * 4 + w) as u64, n))
                .collect();
            for (&wb, w) in io.weights.iter().zip(&ws) {
                inputs.insert(wb, w.clone());
            }
            head_weights.push(ws);
        }

        let t = Instant::now();
        let report = execute_dag(
            &dag,
            &partition,
            &platform,
            &PaperCost,
            &mut Clustering,
            &runtime,
            &inputs,
        )?;
        latencies.push(t.elapsed().as_secs_f64());

        // Verify every head against the fused artifact.
        for (h, io) in ios.iter().enumerate() {
            let got = report
                .store
                .host(io.z_output)
                .expect("head output read back");
            let ws = &head_weights[h];
            let fused = runtime.execute_f32(
                &format!("head_b{beta}"),
                &[&x, &ws[0], &ws[1], &ws[2], &ws[3]],
            )?;
            let max_err = got
                .iter()
                .zip(&fused[0])
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            max_err_overall = max_err_overall.max(max_err);
            assert!(
                max_err < 1e-2,
                "request {req} head {h}: composed vs fused max err {max_err}"
            );
        }
    }
    let wall = served_t0.elapsed().as_secs_f64();

    latencies.sort_by(|a, b| a.total_cmp(b));
    println!("\n== results ==");
    println!(
        "served {requests} requests in {wall:.2}s  ->  {:.2} req/s  ({:.1} heads/s)",
        requests as f64 / wall,
        (requests * heads) as f64 / wall
    );
    println!(
        "latency p50={:.1} ms  p90={:.1} ms  p99={:.1} ms  max={:.1} ms",
        percentile(&latencies, 0.50) * 1e3,
        percentile(&latencies, 0.90) * 1e3,
        percentile(&latencies, 0.99) * 1e3,
        percentile(&latencies, 1.0) * 1e3
    );
    println!("numerics: DAG-composed vs fused-head max |err| = {max_err_overall:.2e}");
    println!("transformer_inference OK");
    Ok(())
}
