//! Fig. 13 reproduction: Gantt charts for eager / HEFT / clustering on the
//! H=16, β=512 transformer layer, with the paper's gap diagnostics.
//!
//! Run: `cargo run --release --example gantt_viz -- [heads] [beta]`

use pyschedcl::report::experiments::gantt;

fn main() -> pyschedcl::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let heads: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(16);
    let beta: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(512);

    println!("== Fig. 13: Gantt charts (H={heads}, β={beta}) ==\n");
    let mut rows = Vec::new();
    for policy in ["eager", "heft", "clustering"] {
        let (r, chart) = gantt(policy, heads, beta)?;
        println!("--- {policy} ---\n{chart}");
        rows.push((policy, r.makespan, r.trace.max_gap(0)));
    }
    println!("summary (paper ordering: eager slowest, clustering fastest & gapless):");
    for (p, makespan, gap) in rows {
        println!(
            "  {p:<11} makespan {:>9.1} ms   max GPU gap {:>8.2} ms",
            makespan * 1e3,
            gap * 1e3
        );
    }
    Ok(())
}
