//! Programmable scheduling (paper feature 2): users can "design, experiment
//! and validate both coarse-grained and fine-grained scheduling policies on
//! top of the default strategies" — here by implementing the [`Policy`]
//! trait.
//!
//! The custom policy below is *GPU-greedy with CPU spill*: it prefers the
//! GPU for every component but, when the GPU is busy and the component is
//! cheap enough on the CPU relative to waiting, spills it — a middle ground
//! between the paper's clustering (strict preference) and eager (no
//! preference).
//!
//! Run: `cargo run --release --example custom_scheduler`

use pyschedcl::cost::PaperCost;
use pyschedcl::platform::{DeviceId, DeviceType, Platform};
use pyschedcl::sched::{Clustering, Eager, Policy, SchedState};
use pyschedcl::sim::{simulate, SimConfig};
use pyschedcl::transformer::{cluster_by_head, transformer_dag};

/// GPU-greedy with cost-aware CPU spill.
struct GpuGreedySpill {
    /// Spill when `cpu_time < spill_factor × (gpu_wait + gpu_time)`.
    spill_factor: f64,
}

impl Policy for GpuGreedySpill {
    fn name(&self) -> &'static str {
        "gpu-greedy-spill"
    }

    fn select(&mut self, state: &mut SchedState) -> Option<(usize, DeviceId)> {
        // Prefer an idle GPU for the head of the rank-ordered frontier —
        // an O(log F) head query on the indexed scheduler state.
        if let Some(gpu) = state.first_available_of(DeviceType::Gpu) {
            let comp = state.rank_head()?;
            return Some((comp, gpu));
        }
        // GPU busy: consider spilling to an idle CPU. `frontier_ranked`
        // is the documented O(F log F) escape hatch for custom policies
        // that genuinely need to walk the whole frontier.
        let cpu = state.first_available_of(DeviceType::Cpu)?;
        let platform = state.platform;
        let gpu_dev = &platform.devices[0];
        for comp in state.frontier_ranked() {
            let cpu_t = state.component_time(comp, platform.device(cpu));
            let gpu_wait = (state.est_free[gpu_dev.id] - state.now).max(0.0);
            let gpu_t = state.component_time(comp, gpu_dev);
            if cpu_t < self.spill_factor * (gpu_wait + gpu_t) {
                return Some((comp, cpu));
            }
        }
        None
    }
}

fn main() -> pyschedcl::Result<()> {
    let heads = 16;
    let beta = 256;
    let (dag, ios) = transformer_dag(heads, beta, DeviceType::Gpu);
    let platform = Platform::paper_testbed(3, 1);
    let cfg = SimConfig::default();

    println!("H={heads} β={beta} on the simulated GTX-970 + i5 testbed\n");
    let part = cluster_by_head(&dag, &ios, 1);
    let base = simulate(&dag, &part, &platform, &PaperCost, &mut Clustering, &cfg)?;
    println!("clustering (h_cpu=1):   {:>8.1} ms", base.makespan * 1e3);

    let all_gpu = cluster_by_head(&dag, &ios, 0);
    for factor in [0.5, 1.0, 2.0] {
        let mut pol = GpuGreedySpill {
            spill_factor: factor,
        };
        let r = simulate(&dag, &all_gpu, &platform, &PaperCost, &mut pol, &cfg)?;
        let cpu_comps = r
            .component_device
            .iter()
            .filter(|&&d| platform.device(d).dtype == DeviceType::Cpu)
            .count();
        println!(
            "{:<22} {:>8.1} ms   ({} head(s) spilled to CPU)",
            format!("spill(f={factor}):"),
            r.makespan * 1e3,
            cpu_comps
        );
    }

    let singles = pyschedcl::graph::Partition::singletons(&dag);
    let p1 = Platform::paper_testbed(1, 1);
    let eg = simulate(&dag, &singles, &p1, &PaperCost, &mut Eager, &cfg)?;
    println!("eager (baseline):       {:>8.1} ms", eg.makespan * 1e3);
    Ok(())
}
